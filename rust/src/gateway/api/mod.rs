//! v2 resource-oriented API: shared context, route table, and JSON
//! helpers. Handlers live in [`functions`], [`invocations`],
//! [`stats`]; the legacy `/v1` query-string surface is kept alive as
//! thin shims in [`v1`].
//!
//! Every v2 error response uses the structured envelope
//! `{"error": {"code": "...", "message": "..."}}` (v1 shims keep their
//! historical flat `{"error": "..."}` shape).

pub mod functions;
pub mod invocations;
pub mod stats;
pub mod traces;
pub mod v1;

use crate::httpd::{error_envelope, HttpRequest, Params, Responder, Router};
use crate::platform::{AsyncInvoker, Platform};
use crate::util::json::{obj, Json};
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

/// Shared state threaded through every handler.
pub struct ApiCtx {
    pub platform: Arc<Platform>,
    pub async_inv: Arc<AsyncInvoker>,
    /// Fallback image-seed sequence when the caller doesn't pass one.
    pub seq: AtomicU64,
}

/// Structured error response (the v2 envelope).
pub fn err(status: u16, code: &str, message: &str) -> Responder {
    Responder::json(status, error_envelope(code, message))
}

/// `Retry-After` hint (whole seconds, floor 1) for throttle
/// responses. The dispatch deadline is how long the platform itself
/// was willing to wait for capacity before giving up, so it is the
/// natural horizon after which a retry has a fresh chance of landing
/// inside a drained queue.
pub fn retry_after_secs(deadline: std::time::Duration) -> u64 {
    (deadline.as_secs_f64().ceil() as u64).max(1)
}

/// The dispatch deadline in effect for `function`: its own override
/// when deployed, else the platform default (also the fallback for
/// unknown names, e.g. an async submit racing an undeploy).
pub fn dispatch_deadline(platform: &Platform, function: &str) -> std::time::Duration {
    match platform.registry.get(function) {
        Ok(spec) => platform.dispatcher.effective_deadline(&spec),
        Err(_) => platform.dispatcher.default_deadline(),
    }
}

/// Parse the request body as JSON; an empty body reads as `{}` so
/// endpoints whose fields all have defaults accept bare POSTs.
pub fn json_body(req: &HttpRequest) -> Result<Json, Responder> {
    if req.body.is_empty() {
        return Ok(obj(vec![]));
    }
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return Err(err(400, "invalid_body", "request body is not valid UTF-8")),
    };
    Json::parse(text).map_err(|e| err(400, "invalid_json", &e.to_string()))
}

/// Optional non-negative integer body field.
pub fn opt_u64(body: &Json, key: &str) -> Result<Option<u64>, Responder> {
    match body.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v.as_u64().map(Some).ok_or_else(|| {
            err(400, "invalid_field", &format!("field {key:?} must be a non-negative integer"))
        }),
    }
}

/// Optional u32 body field: rejects (rather than truncates) values
/// over `u32::MAX`.
pub fn opt_u32(body: &Json, key: &str) -> Result<Option<u32>, Responder> {
    match opt_u64(body, key)? {
        None => Ok(None),
        Some(v) => u32::try_from(v).map(Some).map_err(|_| {
            err(400, "invalid_field", &format!("field {key:?} is out of range"))
        }),
    }
}

/// Tri-state PATCH field: absent = keep (`None`), explicit `null` =
/// clear back to the platform default (`Some(None)`), integer = set
/// (`Some(Some(n))`).
pub fn tri_state_u64(body: &Json, key: &str) -> Result<Option<Option<u64>>, Responder> {
    match body.get(key) {
        None => Ok(None),
        Some(Json::Null) => Ok(Some(None)),
        Some(v) => match v.as_u64() {
            Some(n) => Ok(Some(Some(n))),
            None => Err(err(
                400,
                "invalid_field",
                &format!("field {key:?} must be a non-negative integer or null"),
            )),
        },
    }
}

/// Optional boolean body field.
pub fn opt_bool(body: &Json, key: &str) -> Result<Option<bool>, Responder> {
    match body.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v.as_bool().map(Some).ok_or_else(|| {
            err(400, "invalid_field", &format!("field {key:?} must be a boolean"))
        }),
    }
}

/// Tri-state boolean PATCH field: absent = keep (`None`), explicit
/// `null` = clear back to the platform default (`Some(None)`),
/// boolean = set (`Some(Some(b))`).
pub fn tri_state_bool(body: &Json, key: &str) -> Result<Option<Option<bool>>, Responder> {
    match body.get(key) {
        None => Ok(None),
        Some(Json::Null) => Ok(Some(None)),
        Some(v) => match v.as_bool() {
            Some(b) => Ok(Some(Some(b))),
            None => Err(err(
                400,
                "invalid_field",
                &format!("field {key:?} must be a boolean or null"),
            )),
        },
    }
}

/// Optional string body field.
pub fn opt_str(body: &Json, key: &str) -> Result<Option<String>, Responder> {
    match body.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| err(400, "invalid_field", &format!("field {key:?} must be a string"))),
    }
}

fn bind(
    ctx: &Arc<ApiCtx>,
    f: fn(&ApiCtx, &HttpRequest, &Params) -> Responder,
) -> impl Fn(&HttpRequest, &Params) -> Responder + Send + Sync + 'static {
    let ctx = ctx.clone();
    move |req: &HttpRequest, params: &Params| f(&ctx, req, params)
}

/// The full route table: v2 resources, v1 shims, health.
pub fn build_router(ctx: &Arc<ApiCtx>) -> Router {
    Router::new()
        .route("GET", "/healthz", |_, _| Responder::text(200, "ok"))
        // -- v2 resource-oriented surface --------------------------------
        .route("GET", "/v2/functions", bind(ctx, functions::list))
        .route("POST", "/v2/functions", bind(ctx, functions::create))
        .route("GET", "/v2/functions/:name", bind(ctx, functions::get_one))
        .route("PATCH", "/v2/functions/:name", bind(ctx, functions::patch))
        .route("DELETE", "/v2/functions/:name", bind(ctx, functions::delete))
        .route("POST", "/v2/functions/:name/invocations", bind(ctx, invocations::create))
        .route("GET", "/v2/invocations/:id", bind(ctx, invocations::get_one))
        .route("GET", "/v2/invocations/:id/trace", bind(ctx, traces::invocation_trace))
        .route("GET", "/v2/functions/:name/traces", bind(ctx, traces::function_traces))
        .route("GET", "/v2/functions/:name/stats", bind(ctx, stats::function_stats))
        .route("GET", "/v2/stats", bind(ctx, stats::platform_stats))
        // -- v1 legacy shims ---------------------------------------------
        .route("GET", "/v1/functions", bind(ctx, v1::list))
        .route("POST", "/v1/functions", bind(ctx, v1::deploy))
        .route("GET", "/v1/invoke/:function", bind(ctx, v1::invoke))
        .route("POST", "/v1/prewarm/:function", bind(ctx, v1::prewarm))
        .route("GET", "/v1/stats", bind(ctx, v1::stats))
}
