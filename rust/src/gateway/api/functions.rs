//! `/v2/functions` resource handlers: deploy (POST), list (GET), get
//! (GET /:name), reconfigure (PATCH /:name), undeploy (DELETE /:name).

use super::{err, json_body, opt_bool, opt_str, opt_u32, opt_u64, ApiCtx};
use crate::httpd::{HttpRequest, Params, Responder};
use crate::platform::{FunctionPolicy, FunctionSpec, ReconfigurePatch};
use crate::util::json::{obj, Json};
use std::sync::Arc;

/// Canonical JSON representation of a deployed function.
pub(crate) fn function_json(ctx: &ApiCtx, spec: &Arc<FunctionSpec>) -> Json {
    obj(vec![
        ("name", Json::Str(spec.name.clone())),
        ("model", Json::Str(spec.model.clone())),
        ("variant", Json::Str(spec.variant.clone())),
        ("memory_mb", Json::Num(spec.memory_mb as f64)),
        ("min_warm", Json::Num(spec.min_warm as f64)),
        (
            "max_concurrency",
            match spec.max_concurrency {
                Some(v) => Json::Num(v as f64),
                None => Json::Null,
            },
        ),
        // Admission-queue overrides: null = platform default applies.
        (
            "queue_capacity",
            match spec.queue_capacity {
                Some(v) => Json::Num(v as f64),
                None => Json::Null,
            },
        ),
        (
            "queue_deadline_ms",
            match spec.queue_deadline_ms {
                Some(v) => Json::Num(v as f64),
                None => Json::Null,
            },
        ),
        // Micro-batching overrides: null = platform default applies.
        (
            "max_batch_size",
            match spec.max_batch_size {
                Some(v) => Json::Num(v as f64),
                None => Json::Null,
            },
        ),
        (
            "batch_window_ms",
            match spec.batch_window_ms {
                Some(v) => Json::Num(v as f64),
                None => Json::Null,
            },
        ),
        // Snapshot/restore override: null = platform default applies.
        (
            "snapshot",
            match spec.snapshot {
                Some(v) => Json::Bool(v),
                None => Json::Null,
            },
        ),
        // Adaptive-controller overrides: null = platform default applies.
        (
            "slo_target_ms",
            match spec.slo_target_ms {
                Some(v) => Json::Num(v as f64),
                None => Json::Null,
            },
        ),
        (
            "adaptive",
            match spec.adaptive {
                Some(v) => Json::Bool(v),
                None => Json::Null,
            },
        ),
        ("peak_mem_mb", Json::Num(spec.peak_mem_mb as f64)),
        ("package_mb", Json::Num(spec.package_bytes as f64 / 1e6)),
        ("warm_containers", Json::Num(ctx.platform.pool.warm_count(&spec.name) as f64)),
    ])
}

/// `POST /v2/functions` — deploy from a JSON spec. 201 on success,
/// 409 when the name is already taken (PATCH is the reconfigure verb).
pub fn create(ctx: &ApiCtx, req: &HttpRequest, _params: &Params) -> Responder {
    let body = match json_body(req) {
        Ok(b) => b,
        Err(r) => return r,
    };
    let name = match body.get("name").and_then(Json::as_str) {
        Some(n) => n.to_string(),
        None => return err(400, "missing_field", "body field \"name\" (string) is required"),
    };
    let model = match body.get("model").and_then(Json::as_str) {
        Some(m) => m.to_string(),
        None => return err(400, "missing_field", "body field \"model\" (string) is required"),
    };
    let variant = match opt_str(&body, "variant") {
        Ok(v) => v.unwrap_or_else(|| "pallas".to_string()),
        Err(r) => return r,
    };
    let memory_mb = match opt_u32(&body, "memory_mb") {
        Ok(v) => v.unwrap_or(1024),
        Err(r) => return r,
    };
    let min_warm = match opt_u64(&body, "min_warm") {
        Ok(v) => v.unwrap_or(0) as usize,
        Err(r) => return r,
    };
    let max_concurrency = match opt_u64(&body, "max_concurrency") {
        Ok(v) => v.map(|x| x as usize),
        Err(r) => return r,
    };
    let queue_capacity = match opt_u64(&body, "queue_capacity") {
        Ok(v) => v.map(|x| x as usize),
        Err(r) => return r,
    };
    let queue_deadline_ms = match opt_u64(&body, "queue_deadline_ms") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let max_batch_size = match opt_u64(&body, "max_batch_size") {
        Ok(v) => v.map(|x| x as usize),
        Err(r) => return r,
    };
    let batch_window_ms = match opt_u64(&body, "batch_window_ms") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let snapshot = match opt_bool(&body, "snapshot") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let slo_target_ms = match opt_u64(&body, "slo_target_ms") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let adaptive = match opt_bool(&body, "adaptive") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let conflict = || {
        err(
            409,
            "already_exists",
            &format!(
                "function {name:?} is already deployed; PATCH /v2/functions/{name} to reconfigure"
            ),
        )
    };
    if ctx.platform.registry.get(&name).is_ok() {
        return conflict();
    }
    // create_full is insert-if-absent, so two racing creates cannot
    // both succeed; the loser maps to the same 409 as the pre-check.
    match ctx.platform.create_full(
        &name,
        &model,
        &variant,
        memory_mb,
        FunctionPolicy {
            min_warm,
            max_concurrency,
            queue_capacity,
            queue_deadline_ms,
            max_batch_size,
            batch_window_ms,
            snapshot,
            slo_target_ms,
            adaptive,
        },
    ) {
        Ok(spec) => Responder::json(201, function_json(ctx, &spec).to_string()),
        Err(_) if ctx.platform.registry.get(&name).is_ok() => conflict(),
        Err(e) => err(400, "invalid_deployment", &format!("{e:#}")),
    }
}

/// `GET /v2/functions` — list deployments.
pub fn list(ctx: &ApiCtx, _req: &HttpRequest, _params: &Params) -> Responder {
    let functions: Vec<Json> =
        ctx.platform.registry.list().iter().map(|spec| function_json(ctx, spec)).collect();
    Responder::json(200, obj(vec![("functions", Json::Arr(functions))]).to_string())
}

/// `GET /v2/functions/:name`.
pub fn get_one(ctx: &ApiCtx, _req: &HttpRequest, params: &Params) -> Responder {
    let name = params.require("name");
    match ctx.platform.registry.get(name) {
        Ok(spec) => Responder::json(200, function_json(ctx, &spec).to_string()),
        Err(_) => err(404, "not_found", &format!("function {name:?} is not deployed")),
    }
}

/// `PATCH /v2/functions/:name` — partial reconfigure. Fields absent
/// from the body keep their value; `"max_concurrency": null` clears
/// the cap.
pub fn patch(ctx: &ApiCtx, req: &HttpRequest, params: &Params) -> Responder {
    let name = params.require("name");
    if ctx.platform.registry.get(name).is_err() {
        return err(404, "not_found", &format!("function {name:?} is not deployed"));
    }
    let body = match json_body(req) {
        Ok(b) => b,
        Err(r) => return r,
    };
    let memory_mb = match opt_u32(&body, "memory_mb") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let variant = match opt_str(&body, "variant") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let min_warm = match opt_u64(&body, "min_warm") {
        Ok(v) => v.map(|x| x as usize),
        Err(r) => return r,
    };
    // Tri-state fields: absent = keep, null = clear back to the
    // platform default, integer = set.
    let max_concurrency = match super::tri_state_u64(&body, "max_concurrency") {
        Ok(v) => v.map(|inner| inner.map(|n| n as usize)),
        Err(r) => return r,
    };
    let queue_capacity = match super::tri_state_u64(&body, "queue_capacity") {
        Ok(v) => v.map(|inner| inner.map(|n| n as usize)),
        Err(r) => return r,
    };
    let queue_deadline_ms = match super::tri_state_u64(&body, "queue_deadline_ms") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let max_batch_size = match super::tri_state_u64(&body, "max_batch_size") {
        Ok(v) => v.map(|inner| inner.map(|n| n as usize)),
        Err(r) => return r,
    };
    let batch_window_ms = match super::tri_state_u64(&body, "batch_window_ms") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let snapshot = match super::tri_state_bool(&body, "snapshot") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let slo_target_ms = match super::tri_state_u64(&body, "slo_target_ms") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let adaptive = match super::tri_state_bool(&body, "adaptive") {
        Ok(v) => v,
        Err(r) => return r,
    };
    let patch = ReconfigurePatch {
        memory_mb,
        variant,
        min_warm,
        max_concurrency,
        queue_capacity,
        queue_deadline_ms,
        max_batch_size,
        batch_window_ms,
        snapshot,
        slo_target_ms,
        adaptive,
    };
    match ctx.platform.reconfigure(name, &patch) {
        Ok(spec) => Responder::json(200, function_json(ctx, &spec).to_string()),
        Err(e) => err(400, "invalid_reconfigure", &format!("{e:#}")),
    }
}

/// `DELETE /v2/functions/:name` — undeploy and reap warm containers.
pub fn delete(ctx: &ApiCtx, _req: &HttpRequest, params: &Params) -> Responder {
    let name = params.require("name");
    match ctx.platform.undeploy(name) {
        Ok(reaped) => Responder::json(
            200,
            obj(vec![
                ("deleted", Json::Str(name.to_string())),
                ("reaped_containers", Json::Num(reaped as f64)),
            ])
            .to_string(),
        ),
        Err(_) => err(404, "not_found", &format!("function {name:?} is not deployed")),
    }
}
