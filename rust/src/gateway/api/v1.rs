//! `/v1` legacy shims — byte-compatible with the original query-string
//! gateway on every previously-valid request, so existing experiments,
//! the load generator, and the seed integration tests keep passing
//! unmodified. Two router-level error paths intentionally differ from
//! the old ad-hoc `match`: unknown routes 404 with the structured
//! envelope (was flat `{"error": "no such route"}`), and a known path
//! hit with the wrong method now returns 405 instead of 404. New
//! clients should use `/v2` (see API.md).

use super::{dispatch_deadline, retry_after_secs, ApiCtx};
use crate::httpd::{HttpRequest, Params, Responder};
use crate::platform::InvokeError;
use crate::util::json::{obj, Json};
use std::sync::atomic::Ordering;

/// v1 kept the flat error shape `{"error": "msg"}`.
fn v1_err(msg: &str) -> String {
    obj(vec![("error", Json::Str(msg.to_string()))]).to_string()
}

/// `GET /v1/functions` — bare array of deployment summaries.
pub fn list(ctx: &ApiCtx, _req: &HttpRequest, _params: &Params) -> Responder {
    let fns: Vec<Json> = ctx
        .platform
        .registry
        .list()
        .into_iter()
        .map(|f| {
            obj(vec![
                ("name", Json::Str(f.name.clone())),
                ("model", Json::Str(f.model.clone())),
                ("variant", Json::Str(f.variant.clone())),
                ("memory_mb", Json::Num(f.memory_mb as f64)),
            ])
        })
        .collect();
    Responder::json(200, Json::Arr(fns).to_string())
}

/// `POST /v1/functions?name=&model=&variant=&mem=` — redeploy allowed.
pub fn deploy(ctx: &ApiCtx, req: &HttpRequest, _params: &Params) -> Responder {
    let name = req.query_param("name").unwrap_or_default().to_string();
    let model = req.query_param("model").unwrap_or_default().to_string();
    let variant = req.query_param("variant").unwrap_or("pallas").to_string();
    let mem: u32 = match req.query_param("mem").unwrap_or("1024").parse() {
        Ok(m) => m,
        Err(_) => return Responder::json(400, v1_err("mem must be an integer")),
    };
    match ctx.platform.deploy(&name, &model, &variant, mem) {
        Ok(spec) => Responder::json(
            200,
            obj(vec![
                ("deployed", Json::Str(spec.name.clone())),
                ("memory_mb", Json::Num(spec.memory_mb as f64)),
            ])
            .to_string(),
        ),
        Err(e) => Responder::json(400, v1_err(&e.to_string())),
    }
}

/// `GET /v1/invoke/:function[?seed=N]` — the paper's GET.
pub fn invoke(ctx: &ApiCtx, req: &HttpRequest, params: &Params) -> Responder {
    let func = params.require("function");
    let seed = req
        .query_param("seed")
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| ctx.seq.fetch_add(1, Ordering::Relaxed));
    match ctx.platform.invoke(func, seed) {
        Ok(out) => {
            let r = &out.record;
            Responder::json(
                200,
                obj(vec![
                    ("function", Json::Str(r.function.clone())),
                    ("top1", Json::Num(out.prediction.top1 as f64)),
                    ("top_prob", Json::Num(out.prediction.top_prob as f64)),
                    ("start", Json::Str(r.start.to_string())),
                    ("prediction_s", Json::Num(r.predict.as_secs_f64())),
                    ("response_s", Json::Num(r.response().as_secs_f64())),
                    ("billed_ms", Json::Num(r.billed_ms as f64)),
                    ("cost_dollars", Json::Num(r.cost_dollars)),
                ])
                .to_string(),
            )
        }
        Err(InvokeError::NotFound(f)) => {
            Responder::json(404, v1_err(&format!("function {f} not deployed")))
        }
        Err(InvokeError::Throttled) => {
            let retry = retry_after_secs(dispatch_deadline(&ctx.platform, func));
            Responder::json(429, v1_err("throttled"))
                .with_header("Retry-After", &retry.to_string())
        }
        // Admission-control saturation post-dates the v1 surface;
        // expose it with the proper status (plus the flat v1 error
        // shape) rather than mislabelling it a 429.
        Err(e @ InvokeError::Saturated(_)) => {
            let retry = retry_after_secs(dispatch_deadline(&ctx.platform, func));
            Responder::json(503, v1_err(&e.to_string()))
                .with_header("Retry-After", &retry.to_string())
        }
        Err(InvokeError::Failed(e)) => Responder::json(500, v1_err(&e.to_string())),
    }
}

/// `POST /v1/prewarm/:function?n=N` — keep-warm knob (§5).
pub fn prewarm(ctx: &ApiCtx, req: &HttpRequest, params: &Params) -> Responder {
    let func = params.require("function");
    let n: usize = match req.query_param("n").unwrap_or("1").parse() {
        Ok(n) => n,
        Err(_) => return Responder::json(400, v1_err("n must be an integer")),
    };
    match ctx.platform.prewarm(func, n) {
        Ok(done) => {
            Responder::json(200, obj(vec![("prewarmed", Json::Num(done as f64))]).to_string())
        }
        Err(e) => Responder::json(400, v1_err(&e.to_string())),
    }
}

/// `GET /v1/stats` — original platform-wide snapshot.
pub fn stats(ctx: &ApiCtx, _req: &HttpRequest, _params: &Params) -> Responder {
    let p = &ctx.platform;
    let m = &p.metrics;
    Responder::json(
        200,
        obj(vec![
            ("invocations", Json::Num(m.len() as f64)),
            ("cold_starts", Json::Num(m.cold_count() as f64)),
            ("containers_alive", Json::Num(p.pool.total_alive() as f64)),
            ("in_flight", Json::Num(p.scaler.in_flight() as f64)),
            ("peak_concurrency", Json::Num(p.scaler.high_water_mark() as f64)),
            ("throttled", Json::Num(p.scaler.throttled_count() as f64)),
            ("total_cost_dollars", Json::Num(p.billing.total_dollars())),
            ("total_gb_seconds", Json::Num(p.billing.total_gb_seconds())),
        ])
        .to_string(),
    )
}
