//! Invocation handlers: sync + async `POST
//! /v2/functions/:name/invocations` and the async poll endpoint `GET
//! /v2/invocations/:id`.

use super::{dispatch_deadline, err, json_body, retry_after_secs, ApiCtx};
use crate::httpd::{HttpRequest, Params, Responder};
use crate::platform::{AsyncInvocation, InvocationRecord, InvokeError, SaturationKind};
use crate::runtime::Prediction;
use crate::util::json::{obj, Json};
use std::sync::atomic::Ordering;

/// Canonical JSON for one completed invocation (shared by the sync
/// response, the async result payload, and `/v1/invoke`'s superset).
pub(crate) fn invocation_json(record: &InvocationRecord, prediction: &Prediction) -> Json {
    obj(vec![
        ("function", Json::Str(record.function.clone())),
        ("start", Json::Str(record.start.to_string())),
        ("top1", Json::Num(prediction.top1 as f64)),
        ("top_prob", Json::Num(prediction.top_prob as f64)),
        ("memory_mb", Json::Num(record.memory_mb as f64)),
        ("queue_s", Json::Num(record.queue.as_secs_f64())),
        ("batch_size", Json::Num(record.batch_size as f64)),
        ("batch_wait_s", Json::Num(record.batch_wait.as_secs_f64())),
        ("kernel_batch_n", Json::Num(record.kernel_batch_n as f64)),
        ("predict_s", Json::Num(record.predict.as_secs_f64())),
        ("cold_overhead_s", Json::Num(record.cold_overhead().as_secs_f64())),
        ("response_s", Json::Num(record.response().as_secs_f64())),
        ("billed_ms", Json::Num(record.billed_ms as f64)),
        ("cost_dollars", Json::Num(record.cost_dollars)),
        (
            "trace_id",
            match &record.trace_id {
                Some(id) => Json::Str(id.clone()),
                None => Json::Null,
            },
        ),
    ])
}

/// `POST /v2/functions/:name/invocations` — body `{"seed": N}`
/// optional; `?mode=async` (or body `"mode"`) switches to
/// fire-and-forget and returns `202` + invocation id.
pub fn create(ctx: &ApiCtx, req: &HttpRequest, params: &Params) -> Responder {
    let name = params.require("name");
    let body = match json_body(req) {
        Ok(b) => b,
        Err(r) => return r,
    };
    let seed = body
        .get("seed")
        .and_then(Json::as_u64)
        .or_else(|| req.query_param("seed").and_then(|s| s.parse().ok()))
        .unwrap_or_else(|| ctx.seq.fetch_add(1, Ordering::Relaxed));
    let mode = req
        .query_param("mode")
        .map(str::to_string)
        .or_else(|| body.get("mode").and_then(Json::as_str).map(str::to_string))
        .unwrap_or_else(|| "sync".to_string());
    match mode.as_str() {
        "sync" => sync_invoke(ctx, name, seed),
        "async" => async_invoke(ctx, name, seed),
        other => {
            err(400, "invalid_mode", &format!("mode must be \"sync\" or \"async\", got {other:?}"))
        }
    }
}

fn sync_invoke(ctx: &ApiCtx, name: &str, seed: u64) -> Responder {
    match ctx.platform.invoke(name, seed) {
        Ok(out) => Responder::json(200, invocation_json(&out.record, &out.prediction).to_string()),
        Err(InvokeError::NotFound(f)) => {
            err(404, "not_found", &format!("function {f:?} is not deployed"))
        }
        // 429: the function's own concurrency cap. Retryable once an
        // in-flight request finishes — hint with the same horizon the
        // dispatcher would have waited.
        Err(e @ InvokeError::Throttled) => {
            let retry = retry_after_secs(dispatch_deadline(&ctx.platform, name));
            err(429, "throttled", &e.to_string()).with_header("Retry-After", &retry.to_string())
        }
        // 503: admission queue saturated (full or deadline exhausted).
        Err(e @ InvokeError::Saturated(kind)) => {
            let retry = retry_after_secs(dispatch_deadline(&ctx.platform, name));
            let code = match kind {
                SaturationKind::QueueFull => "queue_full",
                SaturationKind::DeadlineExpired => "queue_deadline_expired",
            };
            err(503, code, &e.to_string()).with_header("Retry-After", &retry.to_string())
        }
        Err(InvokeError::Failed(e)) => err(500, "execution_failed", &format!("{e:#}")),
    }
}

fn async_invoke(ctx: &ApiCtx, name: &str, seed: u64) -> Responder {
    // Fail fast on unknown functions so the 404 arrives at submit
    // time, not buried in a failed result.
    if ctx.platform.registry.get(name).is_err() {
        return err(404, "not_found", &format!("function {name:?} is not deployed"));
    }
    match ctx.async_inv.submit(name, seed) {
        Ok(id) => Responder::json(
            202,
            obj(vec![
                ("invocation_id", Json::Str(id)),
                ("function", Json::Str(name.to_string())),
                ("status", Json::Str("queued".to_string())),
            ])
            .to_string(),
        ),
        Err(e) => {
            let retry = retry_after_secs(dispatch_deadline(&ctx.platform, name));
            err(429, "queue_full", &e.to_string()).with_header("Retry-After", &retry.to_string())
        }
    }
}

/// `GET /v2/invocations/:id` — poll an async invocation.
pub fn get_one(ctx: &ApiCtx, _req: &HttpRequest, params: &Params) -> Responder {
    let id = params.require("id");
    match ctx.async_inv.get(id) {
        Some(entry) => Responder::json(200, async_json(&entry).to_string()),
        None => err(
            404,
            "not_found",
            &format!("invocation {id:?} is unknown or its result expired"),
        ),
    }
}

fn async_json(entry: &AsyncInvocation) -> Json {
    obj(vec![
        ("id", Json::Str(entry.id.clone())),
        ("function", Json::Str(entry.function.clone())),
        ("status", Json::Str(entry.status.as_str().to_string())),
        (
            "result",
            match (&entry.record, &entry.prediction) {
                (Some(record), Some(prediction)) => invocation_json(record, prediction),
                _ => Json::Null,
            },
        ),
        (
            "error",
            match &entry.error {
                Some(e) => Json::Str(e.clone()),
                None => Json::Null,
            },
        ),
    ])
}
