//! Lightweight symbol resolution for the whole-program lint pass.
//!
//! Parses every scoped file's token stream into just enough structure
//! for the call-graph and effect-summary layers: struct fields (with
//! `Mutex`/`RwLock` flags and a *peeled* type name for receiver
//! resolution), `impl`/`trait` blocks, and `fn` items with their
//! parameter types and body token ranges. This is deliberately not a
//! Rust parser — it is a brace/angle-matching walk over the existing
//! tokenizer, conservative in the same way the token rules are:
//! anything it cannot resolve is simply absent, and the downstream
//! analyses treat absence as "unknown", never as "safe" *for declared
//! locks* (an unknown callee contributes no effects; an unknown
//! receiver falls back to name matching, see `callgraph`).
//!
//! "Peeled" types strip the smart-pointer/option wrappers that hide
//! the interesting type from a receiver path: `Arc<dyn Engine>` peels
//! to `Engine`, `Arc<Mutex<BTreeMap<..>>>` peels to its first
//! non-wrapper ident. That is exactly what `self.field.method(...)`
//! resolution needs, because method calls auto-deref through all of
//! them.

use crate::lints::tokenizer::{Tok, TokKind};
use crate::lints::FileCtx;
use std::collections::{BTreeMap, BTreeSet};

/// Wrappers peeled off a field/param type before receiver resolution.
const WRAPPERS: &[&str] = &["Arc", "Rc", "Box", "Weak", "Option", "dyn", "mut"];

/// One struct field or fn parameter, reduced to what resolution needs.
#[derive(Debug, Clone, Default)]
pub struct TypeInfo {
    /// First non-wrapper ident of the declared type, if any.
    pub peeled: Option<String>,
    /// The unpeeled type mentions `Mutex`.
    pub is_mutex: bool,
    /// The unpeeled type mentions `RwLock`.
    pub is_rwlock: bool,
}

/// One `fn` item (free, inherent, trait-default, or trait-decl).
#[derive(Debug)]
pub struct FnDef {
    /// Index into [`Program::files`].
    pub file: usize,
    /// Enclosing `impl`/`trait` type name, `None` for free functions.
    pub self_type: Option<String>,
    pub name: String,
    /// Non-self parameters by name.
    pub params: BTreeMap<String, TypeInfo>,
    /// Token range of the body including both braces; `None` for a
    /// bodyless trait declaration.
    pub body: Option<(usize, usize)>,
    pub has_self: bool,
    /// Declared inside a `trait` block (a default method still gets a
    /// body and is analyzed; a bare declaration has none).
    pub is_trait_decl: bool,
}

/// Symbols of one file, wrapping the shared token context.
pub struct FileSyms {
    pub ctx: FileCtx,
    /// struct name → field name → type info.
    pub structs: BTreeMap<String, BTreeMap<String, TypeInfo>>,
    /// impl type → traits it implements.
    pub impl_traits: BTreeMap<String, BTreeSet<String>>,
}

/// The whole scoped program: every file's symbols plus the flat fn
/// table and the indexes the call graph resolves through.
pub struct Program {
    pub files: Vec<FileSyms>,
    pub fns: Vec<FnDef>,
    /// fn name → indexes into `fns` (test-only fns are excluded: they
    /// are neither analyzed nor valid fallback targets).
    pub by_name: BTreeMap<String, Vec<usize>>,
    /// trait name → impl type names.
    pub trait_impls: BTreeMap<String, Vec<String>>,
}

impl Program {
    /// Parse `(path, source)` pairs into a program. Paths are kept
    /// verbatim (repo-relative in the real run, fixture names in
    /// tests) — the lock table matches on path suffixes.
    pub fn build(files: &[(String, String)]) -> Program {
        let mut out = Program {
            files: Vec::new(),
            fns: Vec::new(),
            by_name: BTreeMap::new(),
            trait_impls: BTreeMap::new(),
        };
        for (path, source) in files {
            let file_idx = out.files.len();
            let ctx = FileCtx::new(path, source);
            let mut fs = FileSyms { ctx, structs: BTreeMap::new(), impl_traits: BTreeMap::new() };
            let fns = parse_file(&mut fs, file_idx);
            for (ty, traits) in &fs.impl_traits {
                for tr in traits {
                    out.trait_impls.entry(tr.clone()).or_default().push(ty.clone());
                }
            }
            for fd in fns {
                let in_test = fd.body.is_some_and(|(s, _)| fs.ctx.is_test[s]);
                if !in_test {
                    out.by_name.entry(fd.name.clone()).or_default().push(out.fns.len());
                    out.fns.push(fd);
                }
            }
            out.files.push(fs);
        }
        out
    }

    /// Resolve `ty.field`'s peeled type across every file's structs.
    pub fn field_type(&self, ty: &str, field: &str) -> Option<String> {
        for fs in &self.files {
            if let Some(fields) = fs.structs.get(ty) {
                if let Some(info) = fields.get(field) {
                    return info.peeled.clone();
                }
            }
        }
        None
    }
}

/// `toks[i]` is `open`; index of the matching `close` (or last token).
pub fn skip_to_matching(toks: &[Tok], mut i: usize, open: &str, close: &str) -> usize {
    let mut depth = 0usize;
    while i < toks.len() {
        if toks[i].kind == TokKind::Punct {
            if toks[i].text == open {
                depth += 1;
            } else if toks[i].text == close {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    return i;
                }
            }
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// Skip a balanced `<...>` generic list when `toks[i]` opens one.
fn skip_generics(toks: &[Tok], i: usize) -> usize {
    if i >= toks.len() || !toks[i].is(TokKind::Punct, "<") {
        return i;
    }
    let mut depth = 0usize;
    let mut j = i;
    while j < toks.len() {
        if toks[j].kind == TokKind::Punct {
            if toks[j].text == "<" {
                depth += 1;
            } else if toks[j].text == ">" {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
        }
        j += 1;
    }
    j
}

fn peel_type(ty: &[Tok]) -> TypeInfo {
    let names: Vec<&str> = ty
        .iter()
        .filter(|t| t.kind == TokKind::Ident)
        .map(|t| t.text.as_str())
        .collect();
    TypeInfo {
        peeled: names.iter().find(|n| !WRAPPERS.contains(n)).map(|n| n.to_string()),
        is_mutex: names.contains(&"Mutex"),
        is_rwlock: names.contains(&"RwLock"),
    }
}

/// One pass over the file: structs, impl/trait contexts, fn items.
fn parse_file(fs: &mut FileSyms, file_idx: usize) -> Vec<FnDef> {
    let toks = &fs.ctx.toks;
    let n = toks.len();
    let mut fns = Vec::new();
    // Stack of (is_trait, type name, close index) for impl/trait blocks.
    let mut ctx: Vec<(bool, Option<String>, usize)> = Vec::new();
    let mut i = 0;
    while i < n {
        while ctx.last().is_some_and(|(_, _, close)| i > *close) {
            ctx.pop();
        }
        if toks[i].kind != TokKind::Ident {
            i += 1;
            continue;
        }
        match toks[i].text.as_str() {
            "struct" if i + 1 < n && toks[i + 1].kind == TokKind::Ident => {
                let name = toks[i + 1].text.clone();
                let j = skip_generics(toks, i + 2);
                if j < n && toks[j].is(TokKind::Punct, "{") {
                    let close = skip_to_matching(toks, j, "{", "}");
                    let fields = parse_fields(toks, j + 1, close);
                    fs.structs.insert(name, fields);
                    i = close + 1;
                } else {
                    i = j + 1;
                }
            }
            "impl" => {
                let mut j = skip_generics(toks, i + 1);
                let mut seg1 = None;
                if j < n && toks[j].kind == TokKind::Ident {
                    seg1 = Some(toks[j].text.clone());
                    j = skip_generics(toks, j + 1);
                }
                let mut trait_name = None;
                let mut ty = seg1.clone();
                if j < n && toks[j].is(TokKind::Ident, "for") {
                    trait_name = seg1;
                    j += 1;
                    while j < n && toks[j].kind == TokKind::Punct && toks[j].text == "&" {
                        j += 1;
                    }
                    if j < n && toks[j].kind == TokKind::Ident {
                        ty = Some(toks[j].text.clone());
                        j += 1;
                    }
                    j = skip_generics(toks, j);
                }
                while j < n && !toks[j].is(TokKind::Punct, "{") {
                    j += 1;
                }
                if j < n {
                    let close = skip_to_matching(toks, j, "{", "}");
                    if let (Some(tr), Some(t)) = (&trait_name, &ty) {
                        fs.impl_traits.entry(t.clone()).or_default().insert(tr.clone());
                    }
                    ctx.push((false, ty, close));
                    i = j + 1;
                } else {
                    i = j;
                }
            }
            "trait" if i + 1 < n && toks[i + 1].kind == TokKind::Ident => {
                let name = toks[i + 1].text.clone();
                let mut j = skip_generics(toks, i + 2);
                while j < n && !toks[j].is(TokKind::Punct, "{") {
                    j += 1;
                }
                if j < n {
                    let close = skip_to_matching(toks, j, "{", "}");
                    ctx.push((true, Some(name), close));
                    i = j + 1;
                } else {
                    i = j;
                }
            }
            "fn" if i + 1 < n && toks[i + 1].kind == TokKind::Ident => {
                let name = toks[i + 1].text.clone();
                let j = skip_generics(toks, i + 2);
                if j >= n || !toks[j].is(TokKind::Punct, "(") {
                    i += 1;
                    continue;
                }
                let close_paren = skip_to_matching(toks, j, "(", ")");
                let (params, has_self) = parse_params(toks, j + 1, close_paren);
                // Body: the next `{` before a `;` (trait decls have none).
                let mut b = close_paren + 1;
                let mut body = None;
                while b < n {
                    if toks[b].is(TokKind::Punct, ";") {
                        break;
                    }
                    if toks[b].is(TokKind::Punct, "{") {
                        body = Some((b, skip_to_matching(toks, b, "{", "}")));
                        break;
                    }
                    b += 1;
                }
                let (is_trait, self_type) = match ctx.last() {
                    Some((t, ty, _)) => (*t, ty.clone()),
                    None => (false, None),
                };
                let next = body.map_or(b + 1, |(_, e)| e + 1);
                fns.push(FnDef {
                    file: file_idx,
                    self_type,
                    name,
                    params,
                    body,
                    has_self,
                    is_trait_decl: is_trait,
                });
                i = next;
            }
            _ => i += 1,
        }
    }
    fns
}

/// Struct body fields: `name : Type ,` at struct-body depth 0.
fn parse_fields(toks: &[Tok], start: usize, end: usize) -> BTreeMap<String, TypeInfo> {
    let mut fields = BTreeMap::new();
    let mut i = start;
    let mut depth = 0i32;
    while i < end {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "{" | "[" | "<" => {
                    depth += 1;
                    i += 1;
                    continue;
                }
                ")" | "}" | "]" | ">" => {
                    depth -= 1;
                    i += 1;
                    continue;
                }
                _ => {}
            }
        }
        let colon_next = i + 1 < end
            && toks[i + 1].is(TokKind::Punct, ":")
            && !(i + 2 < end && toks[i + 2].is(TokKind::Punct, ":"));
        if depth <= 0 && t.kind == TokKind::Ident && colon_next {
            let name = t.text.clone();
            let mut j = i + 2;
            let mut d2 = 0i32;
            let ty_start = j;
            while j < end {
                if toks[j].kind == TokKind::Punct {
                    match toks[j].text.as_str() {
                        "(" | "{" | "[" | "<" => d2 += 1,
                        ")" | "}" | "]" | ">" => d2 -= 1,
                        "," if d2 <= 0 => break,
                        _ => {}
                    }
                }
                j += 1;
            }
            fields.insert(name, peel_type(&toks[ty_start..j]));
            i = j + 1;
            continue;
        }
        i += 1;
    }
    fields
}

/// Param list between the fn's parens: comma-split at depth 0, each
/// segment `name : Type` (or a `self` receiver form).
fn parse_params(toks: &[Tok], start: usize, end: usize) -> (BTreeMap<String, TypeInfo>, bool) {
    let mut params = BTreeMap::new();
    let mut has_self = false;
    let mut segs: Vec<(usize, usize)> = Vec::new();
    let mut depth = 0i32;
    let mut seg_start = start;
    let mut i = start;
    while i < end {
        if toks[i].kind == TokKind::Punct {
            match toks[i].text.as_str() {
                "(" | "{" | "[" | "<" => depth += 1,
                ")" | "}" | "]" | ">" => depth -= 1,
                "," if depth == 0 => {
                    segs.push((seg_start, i));
                    seg_start = i + 1;
                }
                _ => {}
            }
        }
        i += 1;
    }
    if seg_start < end {
        segs.push((seg_start, end));
    }
    for (s, e) in segs {
        let seg = &toks[s..e];
        let idents: Vec<&str> = seg
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .take(2)
            .collect();
        if idents.contains(&"self") {
            has_self = true;
            continue;
        }
        for (j, t) in seg.iter().enumerate() {
            if t.kind == TokKind::Ident && j + 1 < seg.len() && seg[j + 1].is(TokKind::Punct, ":") {
                params.insert(t.text.clone(), peel_type(&seg[j + 2..]));
                break;
            }
        }
    }
    (params, has_self)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prog(src: &str) -> Program {
        Program::build(&[("rust/src/platform/fixture.rs".to_string(), src.to_string())])
    }

    #[test]
    fn struct_fields_peel_wrappers_and_flag_locks() {
        let p = prog(
            "pub struct Pool {\n    idle: Mutex<BTreeMap<String, Vec<Container>>>,\n    engine: Arc<dyn Engine>,\n    shards: RwLock<BTreeMap<String, Arc<Mutex<FnMetrics>>>>,\n    clock: Arc<dyn Clock>,\n}\n",
        );
        let fields = &p.files[0].structs["Pool"];
        assert!(fields["idle"].is_mutex);
        assert!(!fields["idle"].is_rwlock);
        assert!(fields["shards"].is_rwlock);
        assert_eq!(fields["engine"].peeled.as_deref(), Some("Engine"));
        assert_eq!(fields["clock"].peeled.as_deref(), Some("Clock"));
    }

    #[test]
    fn impl_and_trait_methods_get_self_types() {
        let p = prog(
            "pub struct A;\nimpl A {\n    pub fn m(&self, x: u32) {}\n}\ntrait T {\n    fn d(&self) { }\n    fn decl(&self);\n}\nimpl T for A {\n    fn decl(&self) {}\n}\nfn free(n: usize) {}\n",
        );
        let names: Vec<(Option<&str>, &str, bool)> = p
            .fns
            .iter()
            .map(|f| (f.self_type.as_deref(), f.name.as_str(), f.is_trait_decl))
            .collect();
        assert!(names.contains(&(Some("A"), "m", false)));
        assert!(names.contains(&(Some("T"), "d", true)), "{names:?}");
        assert!(names.contains(&(Some("A"), "decl", false)));
        assert!(names.contains(&(None, "free", false)));
        let decl = p.fns.iter().find(|f| f.name == "decl" && f.is_trait_decl).unwrap();
        assert!(decl.body.is_none(), "bodyless trait declaration");
        assert_eq!(p.trait_impls["T"], vec!["A".to_string()]);
    }

    #[test]
    fn params_resolve_and_self_is_detected() {
        let p = prog("fn f(rng: &Mutex<SplitMix64>, pool: &WarmPool) {}\n");
        let f = &p.fns[0];
        assert!(f.params["rng"].is_mutex);
        assert_eq!(f.params["pool"].peeled.as_deref(), Some("WarmPool"));
        assert!(!f.has_self);
    }

    #[test]
    fn test_fns_are_excluded_from_the_index() {
        let p = prog(
            "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n}\n",
        );
        assert!(p.by_name.contains_key("live"));
        assert!(!p.by_name.contains_key("helper"));
    }

    #[test]
    fn field_type_resolves_across_files() {
        let p = Program::build(&[
            ("a.rs".to_string(), "pub struct X { pool: Arc<WarmPool> }\n".to_string()),
            ("b.rs".to_string(), "pub struct WarmPool { n: u32 }\n".to_string()),
        ]);
        assert_eq!(p.field_type("X", "pool").as_deref(), Some("WarmPool"));
        assert_eq!(p.field_type("X", "missing"), None);
    }
}
