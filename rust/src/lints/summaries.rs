//! Per-function effect summaries and their transitive closure.
//!
//! For every non-test function in scope, one pass over its body tokens
//! (with the same conservative guard-liveness simulation the old
//! per-file `lock-order` rule used) produces a list of [`Event`]s:
//!
//! - **Acquire** — a tracked platform lock is taken (`plock(&path)`,
//!   `path.lock()`, or `path.read()`/`path.write()` on a declared
//!   `RwLock` site), with the set of locks already held;
//! - **Block** — a potentially-unbounded pause: condvar wait, clock
//!   sleep, channel recv, zero-arg `join()`, or one of the blocking
//!   `Engine` methods (`predict`, `create_instance`, ...). Engine
//!   calls are modeled as opaque blocking leaves at the trait
//!   boundary rather than resolved into a particular engine impl;
//! - **Call** — a resolvable call edge (see [`crate::lints::callgraph`])
//!   with the held-lock snapshot at the call site.
//!
//! Anything inside a `spawn(...)` argument list — bare `spawn(`,
//! `thread::spawn(`, or builder-style `.spawn(` — is excluded: it runs
//! on another thread and holds nothing of ours.
//!
//! The per-function `acquires`/`blocks` sets are then propagated
//! callee→caller over the call graph with a worklist until fixpoint
//! (set-union is monotone, so recursion — mutual or direct — simply
//! converges). Each propagated fact keeps a [`Witness`] back-pointer,
//! so a finding two hops up can print the actual chain:
//! `dispatcher.rs:Dispatcher::f -> helper.rs:Helper::b -> line 12`.

use crate::lints::callgraph::resolve_method;
use crate::lints::rules::lock_order::{is_rw_site, lock_for};
use crate::lints::symbols::{skip_to_matching, Program};
use crate::lints::tokenizer::{Tok, TokKind};
use std::collections::{BTreeMap, BTreeSet};

/// `Engine` trait methods that can stall for model-serving reasons
/// (compilation, weight transfer, inference). `drop_instance` is
/// deliberately absent: it is bounded bookkeeping.
pub const ENGINE_BLOCKING: &[&str] =
    &["predict", "predict_batch", "create_instance", "snapshot_instance", "restore_instance"];

/// One tracked lock held at an event, as seen by the simulation.
#[derive(Debug, Clone)]
pub struct HeldLock {
    /// Index into [`crate::lints::rules::lock_order::PLATFORM_LOCK_ORDER`].
    pub lock: usize,
    /// Acquisition line.
    pub line: u32,
    /// `Some(var)` for `let var = …` guards, `None` for temporaries.
    pub binding: Option<String>,
}

#[derive(Debug, Clone)]
pub enum EventKind {
    /// Acquires the tracked lock with this rank index.
    Acquire(usize),
    /// Calls a resolved method/function; `cands` indexes `Program::fns`.
    Call { name: String, cands: Vec<usize> },
    /// Blocks directly. `kind` is a stable id (`condvar-wait`,
    /// `clock-sleep`, `channel-recv`, `thread-join`,
    /// `engine-call:<method>`). For condvar waits, `own_guard` is the
    /// guard variable the wait consumes (that one is *released* while
    /// parked and is exempt from blocking-under-lock).
    Block { kind: String, own_guard: Option<String> },
}

#[derive(Debug, Clone)]
pub struct Event {
    pub kind: EventKind,
    pub line: u32,
    /// Snapshot of tracked locks held when the event fires.
    pub held: Vec<HeldLock>,
}

/// How a transitive fact entered a function's summary.
#[derive(Debug, Clone, Copy)]
pub enum Witness {
    /// Happens directly in this function, at this line.
    Direct(u32),
    /// Inherited from this callee (index into `Program::fns`).
    Via(usize),
}

/// The computed whole-program summaries, indexed by `Program::fns`.
pub struct Summaries {
    pub events: Vec<Vec<Event>>,
    /// Transitive closure: locks a call to fn `i` may acquire.
    pub acquires: Vec<BTreeSet<usize>>,
    /// Transitive closure: block kinds a call to fn `i` may hit.
    pub blocks: Vec<BTreeSet<String>>,
    via_acq: BTreeMap<(usize, usize), Witness>,
    via_blk: BTreeMap<(usize, String), Witness>,
}

impl Summaries {
    /// Human-readable chain explaining why fn `f` transitively
    /// acquires `lock`: `pool.rs:WarmPool::take -> ... -> line 80`.
    pub fn acquire_chain(&self, p: &Program, f: usize, lock: usize) -> String {
        self.chain(p, f, |s, cur| s.via_acq.get(&(cur, lock)).copied())
    }

    /// Chain explaining why fn `f` transitively blocks with `kind`.
    pub fn block_chain(&self, p: &Program, f: usize, kind: &str) -> String {
        self.chain(p, f, |s, cur| s.via_blk.get(&(cur, kind.to_string())).copied())
    }

    fn chain(
        &self,
        p: &Program,
        f: usize,
        step: impl Fn(&Self, usize) -> Option<Witness>,
    ) -> String {
        let mut parts = vec![short_name(p, f)];
        let mut cur = f;
        // Bounded walk: witnesses are acyclic by construction (each
        // points at the callee the fact was first copied from), but a
        // cap keeps a future bug from looping the linter.
        for _ in 0..50 {
            match step(self, cur) {
                Some(Witness::Direct(line)) => {
                    parts.push(format!("line {line}"));
                    break;
                }
                Some(Witness::Via(callee)) => {
                    parts.push(short_name(p, callee));
                    cur = callee;
                }
                None => break,
            }
        }
        parts.join(" -> ")
    }
}

/// `pool.rs:WarmPool::take` — compact fn identifier for messages.
pub fn short_name(p: &Program, f: usize) -> String {
    let fd = &p.fns[f];
    let path = &p.files[fd.file].ctx.path;
    let base = path.rsplit('/').next().unwrap_or(path);
    match &fd.self_type {
        Some(st) => format!("{base}:{st}::{}", fd.name),
        None => format!("{base}:{}", fd.name),
    }
}

/// Build every function's event list and close the summaries over the
/// call graph.
pub fn compute(p: &Program) -> Summaries {
    let n = p.fns.len();
    let mut events = Vec::with_capacity(n);
    for idx in 0..n {
        events.push(extract_effects(p, idx));
    }
    let mut acquires: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n];
    let mut blocks: Vec<BTreeSet<String>> = vec![BTreeSet::new(); n];
    let mut via_acq: BTreeMap<(usize, usize), Witness> = BTreeMap::new();
    let mut via_blk: BTreeMap<(usize, String), Witness> = BTreeMap::new();
    let mut calls: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n];
    for (idx, evs) in events.iter().enumerate() {
        for e in evs {
            match &e.kind {
                EventKind::Acquire(l) => {
                    acquires[idx].insert(*l);
                    via_acq.entry((idx, *l)).or_insert(Witness::Direct(e.line));
                }
                EventKind::Block { kind, .. } => {
                    blocks[idx].insert(kind.clone());
                    via_blk.entry((idx, kind.clone())).or_insert(Witness::Direct(e.line));
                }
                EventKind::Call { cands, .. } => {
                    calls[idx].extend(cands.iter().copied());
                }
            }
        }
    }
    // Worklist over reverse edges: when a callee's summary grows, its
    // callers re-absorb it. Union is monotone over finite sets, so
    // this terminates even through recursion cycles.
    let mut callers: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n];
    for (idx, cs) in calls.iter().enumerate() {
        for &c in cs {
            callers[c].insert(idx);
        }
    }
    let mut work: Vec<usize> = (0..n).collect();
    while let Some(f) = work.pop() {
        let f_acq: Vec<usize> = acquires[f].iter().copied().collect();
        let f_blk: Vec<String> = blocks[f].iter().cloned().collect();
        let cs: Vec<usize> = callers[f].iter().copied().collect();
        for caller in cs {
            let mut changed = false;
            for &l in &f_acq {
                if acquires[caller].insert(l) {
                    via_acq.entry((caller, l)).or_insert(Witness::Via(f));
                    changed = true;
                }
            }
            for b in &f_blk {
                if blocks[caller].insert(b.clone()) {
                    via_blk.entry((caller, b.clone())).or_insert(Witness::Via(f));
                    changed = true;
                }
            }
            if changed {
                work.push(caller);
            }
        }
    }
    Summaries { events, acquires, blocks, via_acq, via_blk }
}

/// Internal guard state: a [`HeldLock`] plus the brace depth it was
/// born at (for block-scoped release).
struct GuardState {
    lock: usize,
    line: u32,
    binding: Option<String>,
    depth: usize,
}

/// One pass over fn `fn_idx`'s body: guard-liveness simulation plus
/// event extraction. Mirrors the old per-file rule's liveness model:
/// let-bound guards live until `drop(name)` or their block closes;
/// temporaries die at their statement's `;` (or the `}` of an attached
/// block, matching Rust's temporary-scope extension for `if let`).
fn extract_effects(p: &Program, fn_idx: usize) -> Vec<Event> {
    let fd = &p.fns[fn_idx];
    let fs = &p.files[fd.file];
    let toks = &fs.ctx.toks;
    let path = &fs.ctx.path;
    let Some((start, end)) = fd.body else { return Vec::new() };
    let mut events: Vec<Event> = Vec::new();
    let mut held: Vec<GuardState> = Vec::new();
    let mut depth = 0usize;
    let mut i = start;
    while i <= end {
        let t = &toks[i];
        if t.kind == TokKind::Comment {
            i += 1;
            continue;
        }
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => {
                    depth += 1;
                    i += 1;
                    continue;
                }
                "}" => {
                    depth = depth.saturating_sub(1);
                    held.retain(|g| g.depth <= depth && !(g.binding.is_none() && g.depth == depth));
                    i += 1;
                    continue;
                }
                ";" => {
                    held.retain(|g| !(g.binding.is_none() && g.depth == depth));
                    i += 1;
                    continue;
                }
                _ => {}
            }
        }
        if fs.ctx.is_test[i] {
            i += 1;
            continue;
        }
        // `drop(name)` releases a let-bound guard early.
        if t.is(TokKind::Ident, "drop")
            && i + 3 <= end
            && toks[i + 1].is(TokKind::Punct, "(")
            && toks[i + 2].kind == TokKind::Ident
            && toks[i + 3].is(TokKind::Punct, ")")
        {
            let name = toks[i + 2].text.as_str();
            held.retain(|g| g.binding.as_deref() != Some(name));
            i += 4;
            continue;
        }
        // `spawn(...)` runs on another thread: its argument list
        // (usually a closure) contributes nothing to THIS function's
        // effects. Catches bare `spawn(` and, via the call branch
        // below, `thread::spawn(` / builder `.spawn(`.
        if t.is(TokKind::Ident, "spawn") && i + 1 <= end && toks[i + 1].is(TokKind::Punct, "(") {
            i = skip_to_matching(toks, i + 1, "(", ")") + 1;
            continue;
        }
        let snap: Vec<HeldLock> = held
            .iter()
            .map(|g| HeldLock { lock: g.lock, line: g.line, binding: g.binding.clone() })
            .collect();
        // ---- blocking operations -----------------------------------
        // `pwait_timeout(&cv, guard, dur)` — the own guard is arg #2.
        if t.is(TokKind::Ident, "pwait_timeout")
            && i + 1 <= end
            && toks[i + 1].is(TokKind::Punct, "(")
            && !(i > 0 && toks[i - 1].is(TokKind::Punct, "."))
        {
            let mut own = None;
            let mut j = i + 2;
            let mut d2 = 1usize;
            let mut commas = 0;
            while j <= end && d2 > 0 {
                if toks[j].kind == TokKind::Punct {
                    match toks[j].text.as_str() {
                        "(" => d2 += 1,
                        ")" => d2 -= 1,
                        "," if d2 == 1 => {
                            commas += 1;
                            if commas == 1 && j + 1 <= end && toks[j + 1].kind == TokKind::Ident {
                                own = Some(toks[j + 1].text.clone());
                            }
                        }
                        _ => {}
                    }
                }
                j += 1;
            }
            events.push(Event {
                kind: EventKind::Block { kind: "condvar-wait".to_string(), own_guard: own },
                line: t.line,
                held: snap,
            });
            i += 1;
            continue;
        }
        if t.is(TokKind::Punct, ".")
            && i + 2 <= end
            && toks[i + 1].kind == TokKind::Ident
            && toks[i + 2].is(TokKind::Punct, "(")
        {
            let m = toks[i + 1].text.as_str();
            let line = toks[i + 1].line;
            let zero_arg = i + 3 <= end && toks[i + 3].is(TokKind::Punct, ")");
            if (m == "wait" || m == "wait_timeout") && !zero_arg {
                let own = (i + 3 <= end && toks[i + 3].kind == TokKind::Ident)
                    .then(|| toks[i + 3].text.clone());
                events.push(Event {
                    kind: EventKind::Block { kind: "condvar-wait".to_string(), own_guard: own },
                    line,
                    held: snap,
                });
                i += 2;
                continue;
            }
            if m == "sleep" {
                events.push(Event {
                    kind: EventKind::Block { kind: "clock-sleep".to_string(), own_guard: None },
                    line,
                    held: snap,
                });
                i += 2;
                continue;
            }
            if m == "recv" || m == "recv_timeout" {
                events.push(Event {
                    kind: EventKind::Block { kind: "channel-recv".to_string(), own_guard: None },
                    line,
                    held: snap,
                });
                i += 2;
                continue;
            }
            if m == "join" && zero_arg {
                events.push(Event {
                    kind: EventKind::Block { kind: "thread-join".to_string(), own_guard: None },
                    line,
                    held: snap,
                });
                i += 2;
                continue;
            }
            if ENGINE_BLOCKING.contains(&m) {
                events.push(Event {
                    kind: EventKind::Block { kind: format!("engine-call:{m}"), own_guard: None },
                    line,
                    held: snap,
                });
                i += 2;
                continue;
            }
        }
        // ---- acquisitions ------------------------------------------
        // `plock(&path)`.
        if t.is(TokKind::Ident, "plock")
            && i + 2 <= end
            && toks[i + 1].is(TokKind::Punct, "(")
            && toks[i + 2].is(TokKind::Punct, "&")
        {
            if let Some(name) = plain_path_after(toks, i + 3) {
                if let Some(lid) = lock_for(path, &name) {
                    do_acquire(&mut events, &mut held, toks, i, depth, lid, snap);
                }
            }
            i += 1;
            continue;
        }
        // `path.lock()` / `path.read()` / `path.write()` — zero-arg
        // only, so `stream.write(buf)` can never look like an RwLock.
        if t.is(TokKind::Punct, ".")
            && i + 3 <= end
            && toks[i + 1].kind == TokKind::Ident
            && toks[i + 2].is(TokKind::Punct, "(")
            && toks[i + 3].is(TokKind::Punct, ")")
        {
            let m = toks[i + 1].text.as_str();
            if m == "lock" || m == "read" || m == "write" {
                let (segs, pstart) = path_before_idx(toks, i);
                if let Some(name) = segs.last() {
                    if let Some(lid) = lock_for(path, name) {
                        if m == "lock" || is_rw_site(path, name) {
                            do_acquire(&mut events, &mut held, toks, pstart, depth, lid, snap);
                            i += 4;
                            continue;
                        }
                    }
                }
            }
        }
        // ---- call sites --------------------------------------------
        // Method call `recv.path.m(`.
        if t.is(TokKind::Punct, ".")
            && i + 2 <= end
            && toks[i + 1].kind == TokKind::Ident
            && toks[i + 2].is(TokKind::Punct, "(")
        {
            let m = toks[i + 1].text.clone();
            if m == "spawn" {
                i = skip_to_matching(toks, i + 2, "(", ")") + 1;
                continue;
            }
            let (segs, _) = path_before_idx(toks, i);
            let cands = resolve_method(p, fd, &segs, &m);
            if !cands.is_empty() {
                events.push(Event {
                    kind: EventKind::Call { name: m, cands },
                    line: toks[i + 1].line,
                    held: snap,
                });
            }
            i += 2;
            continue;
        }
        // Free-function call `f(` (not `.f(`, not `::f(`).
        if t.kind == TokKind::Ident
            && i + 1 <= end
            && toks[i + 1].is(TokKind::Punct, "(")
            && !(i > 0 && toks[i - 1].is(TokKind::Punct, "."))
            && !(i > 0 && toks[i - 1].is(TokKind::Punct, ":"))
        {
            let cands: Vec<usize> = p
                .by_name
                .get(&t.text)
                .map(|v| {
                    v.iter()
                        .copied()
                        .filter(|&fi| !p.fns[fi].has_self && p.fns[fi].self_type.is_none())
                        .collect()
                })
                .unwrap_or_default();
            if !cands.is_empty() {
                events.push(Event {
                    kind: EventKind::Call { name: t.text.clone(), cands },
                    line: t.line,
                    held: snap,
                });
            }
            i += 1;
            continue;
        }
        // Qualified call `Type::method(` (incl. `Self::`).
        if t.kind == TokKind::Ident
            && i + 4 <= end
            && toks[i + 1].is(TokKind::Punct, ":")
            && toks[i + 2].is(TokKind::Punct, ":")
            && toks[i + 3].kind == TokKind::Ident
            && toks[i + 4].is(TokKind::Punct, "(")
        {
            let m = toks[i + 3].text.clone();
            if m == "spawn" {
                i = skip_to_matching(toks, i + 4, "(", ")") + 1;
                continue;
            }
            let qual =
                if t.text == "Self" { fd.self_type.clone() } else { Some(t.text.clone()) };
            let cands: Vec<usize> = p
                .by_name
                .get(&m)
                .map(|v| {
                    v.iter().copied().filter(|&fi| p.fns[fi].self_type == qual).collect()
                })
                .unwrap_or_default();
            if !cands.is_empty() {
                events.push(Event {
                    kind: EventKind::Call { name: m, cands },
                    line: toks[i + 3].line,
                    held: snap,
                });
            }
            i += 5;
            continue;
        }
        i += 1;
    }
    events
}

fn do_acquire(
    events: &mut Vec<Event>,
    held: &mut Vec<GuardState>,
    toks: &[Tok],
    start: usize,
    depth: usize,
    lid: usize,
    snap: Vec<HeldLock>,
) {
    let line = toks[start].line;
    events.push(Event { kind: EventKind::Acquire(lid), line, held: snap });
    // `let g = …` / `let mut g = …` binds the guard; else temporary.
    let binding = if start >= 3
        && toks[start - 1].is(TokKind::Punct, "=")
        && toks[start - 2].kind == TokKind::Ident
        && (toks[start - 3].is(TokKind::Ident, "let")
            || (start >= 4
                && toks[start - 3].is(TokKind::Ident, "mut")
                && toks[start - 4].is(TokKind::Ident, "let")))
    {
        Some(toks[start - 2].text.clone())
    } else {
        None
    };
    // Rebinding a name implicitly drops the old guard.
    if let Some(b) = &binding {
        held.retain(|g| g.binding.as_deref() != Some(b.as_str()));
    }
    held.push(GuardState { lock: lid, line, binding, depth });
}

/// Forward-parse `ident (. ident)*` at `toks[i]`, requiring the next
/// token to be `)`. Returns the final segment (the lock field name),
/// or `None` for computed receivers.
fn plain_path_after(toks: &[Tok], mut i: usize) -> Option<String> {
    let mut last: Option<String> = None;
    loop {
        if i >= toks.len() || toks[i].kind != TokKind::Ident {
            return None;
        }
        last = Some(toks[i].text.clone());
        i += 1;
        if i < toks.len() && toks[i].is(TokKind::Punct, ".") {
            i += 1;
            continue;
        }
        break;
    }
    if i < toks.len() && toks[i].is(TokKind::Punct, ")") {
        last
    } else {
        None
    }
}

/// Backward-parse the `ident (. ident)*` path ending at `toks[end]`
/// (exclusive). Returns the segments and the index of the path's first
/// token (where `let`-binding detection starts).
fn path_before_idx(toks: &[Tok], end: usize) -> (Vec<String>, usize) {
    let mut segs: Vec<String> = Vec::new();
    let mut i = end;
    loop {
        if i == 0 || toks[i - 1].kind != TokKind::Ident {
            break;
        }
        segs.push(toks[i - 1].text.clone());
        i -= 1;
        if i == 0 || !toks[i - 1].is(TokKind::Punct, ".") {
            break;
        }
        i -= 1;
    }
    segs.reverse();
    (segs, i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summarize(src: &str) -> (Program, Summaries) {
        let p = Program::build(&[("rust/src/platform/pool.rs".to_string(), src.to_string())]);
        let s = compute(&p);
        (p, s)
    }

    fn fn_idx(p: &Program, name: &str) -> usize {
        p.fns.iter().position(|f| f.name == name).unwrap()
    }

    #[test]
    fn direct_acquire_and_block_land_in_summaries() {
        let (p, s) = summarize(
            "pub struct WarmPool { idle: Mutex<u32>, clock: Arc<dyn Clock> }\nimpl WarmPool {\n    fn f(&self) {\n        let g = plock(&self.idle);\n        drop(g);\n        self.clock.sleep(d);\n    }\n}\n",
        );
        let f = fn_idx(&p, "f");
        assert!(s.acquires[f].contains(&super::super::rules::lock_order::rank_of("pool.idle")));
        assert!(s.blocks[f].contains("clock-sleep"));
    }

    #[test]
    fn effects_propagate_to_fixpoint_through_recursion() {
        let (p, s) = summarize(
            "pub struct WarmPool { clock: Arc<dyn Clock> }\nimpl WarmPool {\n    fn ping(&self, n: u32) { if n > 0 { self.pong(n); } }\n    fn pong(&self, n: u32) { self.clock.sleep(d); self.ping(n - 1); }\n}\n",
        );
        assert!(s.blocks[fn_idx(&p, "ping")].contains("clock-sleep"), "inherited from pong");
        assert!(s.blocks[fn_idx(&p, "pong")].contains("clock-sleep"));
    }

    #[test]
    fn spawn_bodies_are_another_threads_problem() {
        let (p, s) = summarize(
            "pub struct WarmPool { clock: Arc<dyn Clock> }\nimpl WarmPool {\n    fn a(&self) { spawn(move || self.clock.sleep(d)); }\n    fn b(&self) { std::thread::Builder::new().name(n).spawn(move || self.clock.sleep(d)); }\n    fn c(&self) { thread::spawn(move || self.clock.sleep(d)); }\n}\n",
        );
        for name in ["a", "b", "c"] {
            assert!(s.blocks[fn_idx(&p, name)].is_empty(), "{name} must not inherit the closure");
        }
    }

    #[test]
    fn block_chain_names_the_hops() {
        let (p, s) = summarize(
            "pub struct WarmPool { clock: Arc<dyn Clock> }\nimpl WarmPool {\n    fn outer(&self) { self.inner(); }\n    fn inner(&self) { self.clock.sleep(d); }\n}\n",
        );
        let chain = s.block_chain(&p, fn_idx(&p, "outer"), "clock-sleep");
        assert!(chain.contains("WarmPool::outer"), "{chain}");
        assert!(chain.contains("WarmPool::inner"), "{chain}");
        assert!(chain.contains("line "), "{chain}");
    }

    #[test]
    fn engine_calls_are_opaque_blocking_leaves() {
        let (p, s) = summarize(
            "pub struct WarmPool { engine: Arc<dyn Engine> }\nimpl WarmPool {\n    fn f(&self) { self.engine.predict(x); }\n}\n",
        );
        assert!(s.blocks[fn_idx(&p, "f")].contains("engine-call:predict"));
    }
}
