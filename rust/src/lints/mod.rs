//! `pallas-lint`: in-repo static analysis for the platform's
//! concurrency and virtual-clock invariants.
//!
//! The serving layer's tail-latency claims rest on hand-rolled
//! concurrency — waitable pools, batch leaders, capture fences — and
//! on every wait and timestamp flowing through the [`Clock`] trait so
//! `ManualClock` tests stay fully virtualized. Those invariants are
//! machine-checked here rather than left as tribal knowledge. Seven
//! rules (see `LINTS.md` at the repo root for the rationale of each):
//!
//! | rule id               | invariant                                          |
//! |-----------------------|----------------------------------------------------|
//! | `wall-clock`          | no `Instant::now`/`SystemTime::now`/`thread::sleep` in platform/gateway/runtime non-test code |
//! | `naked-condvar-wait`  | every condvar wait is bounded (`wait_timeout`)     |
//! | `global-lock-order`   | every acquisition path — intra- or interprocedural — respects the one global lock rank table; no re-entry, no cycles, no stale table rows |
//! | `blocking-under-lock` | no tracked guard live across a blocking operation (condvar wait, clock sleep, channel recv, thread join, engine call), even via callees |
//! | `poisoned-lock-unwrap`| `.lock().unwrap()` must be the poison-tolerant `plock()` |
//! | `stats-doc-drift`     | stats JSON fields and API.md stay in sync          |
//! | `config-doc-drift`    | parsed `[platform]`/`[snapshot]` TOML keys and API.md stay in sync |
//!
//! The first two and `poisoned-lock-unwrap` are per-file token rules.
//! `global-lock-order` and `blocking-under-lock` are **whole-program**:
//! [`symbols`] parses every scoped file into structs/impls/fns,
//! [`callgraph`] resolves call sites by receiver type (with a
//! deny-listed name-match fallback), and [`summaries`] closes
//! per-function effect summaries (locks acquired, ways of blocking)
//! over the call graph to a fixpoint, so a deadlock assembled from two
//! individually-clean files is still visible.
//!
//! Findings can be suppressed with `// lint:allow(rule-id: reason)` on
//! the same or the preceding line; the reason is mandatory — an allow
//! without one is itself a finding. The suite runs as a tier-1 test
//! ([`tests::repo_tree_is_lint_clean`]) and as the `pallas_lint`
//! binary in CI (`-D`, `--json`, `--timing`).
//!
//! [`Clock`]: crate::util::Clock

pub mod callgraph;
pub mod rules;
pub mod summaries;
pub mod symbols;
pub mod tokenizer;

use crate::util::json::{obj, Json};
use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::time::Duration;
use tokenizer::{tokenize, Tok, TokKind};

/// Rule identifiers (the `rule-id` accepted by `lint:allow`).
pub const WALL_CLOCK: &str = "wall-clock";
pub const NAKED_CONDVAR_WAIT: &str = "naked-condvar-wait";
pub const GLOBAL_LOCK_ORDER: &str = "global-lock-order";
pub const BLOCKING_UNDER_LOCK: &str = "blocking-under-lock";
pub const POISONED_LOCK_UNWRAP: &str = "poisoned-lock-unwrap";
pub const STATS_DOC_DRIFT: &str = "stats-doc-drift";
pub const CONFIG_DOC_DRIFT: &str = "config-doc-drift";
/// Meta-rule: malformed `lint:allow` (missing rule id or reason).
pub const LINT_ALLOW: &str = "lint-allow";

/// Every registered rule id, in report order.
pub const ALL_RULES: &[&str] = &[
    WALL_CLOCK,
    NAKED_CONDVAR_WAIT,
    GLOBAL_LOCK_ORDER,
    BLOCKING_UNDER_LOCK,
    POISONED_LOCK_UNWRAP,
    STATS_DOC_DRIFT,
    CONFIG_DOC_DRIFT,
    LINT_ALLOW,
];

/// Timing label for the shared symbol/call-graph/summary construction
/// that the two whole-program rules consume.
pub const SUMMARIES_PHASE: &str = "(call-graph + summaries)";

/// Directories under `rust/src/` whose non-test code the concurrency
/// rules scan. `util/` (the clock itself), `httpd` (a real socket
/// transport), the simulation harness, and the lints are out of
/// scope by construction.
const SCOPED_DIRS: &[&str] = &["platform", "gateway", "runtime"];

/// One rule violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    /// Path relative to the repository root.
    pub file: String,
    /// 1-indexed; 0 for whole-file findings (doc drift, staleness).
    pub line: u32,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

impl Finding {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("rule", Json::Str(self.rule.to_string())),
            ("file", Json::Str(self.file.clone())),
            ("line", Json::Num(self.line as f64)),
            ("message", Json::Str(self.message.clone())),
        ])
    }
}

/// A parsed `lint:allow(rule-id: reason)` comment.
#[derive(Debug, Clone)]
pub struct Suppression {
    pub rule: String,
    pub reason: String,
    pub line: u32,
}

/// One tokenized source file plus the derived per-token facts the
/// rules share.
pub struct FileCtx {
    /// Repo-relative path with forward slashes (lock-table suffixes
    /// match against this).
    pub path: String,
    pub toks: Vec<Tok>,
    /// `is_test[i]` — token `i` sits inside a `#[cfg(test)]` item.
    pub is_test: Vec<bool>,
}

impl FileCtx {
    pub fn new(path: &str, source: &str) -> Self {
        let toks = tokenize(source);
        let is_test = mark_cfg_test_regions(&toks);
        Self { path: path.to_string(), toks, is_test }
    }
}

/// Mark the token span of every `#[cfg(test)]` item (attribute through
/// the matching close brace of the item's body, or through the `;` of
/// a braceless item).
fn mark_cfg_test_regions(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0;
    while i + 6 < toks.len() {
        let attr = toks[i].is(TokKind::Punct, "#")
            && toks[i + 1].is(TokKind::Punct, "[")
            && toks[i + 2].is(TokKind::Ident, "cfg")
            && toks[i + 3].is(TokKind::Punct, "(")
            && toks[i + 4].is(TokKind::Ident, "test")
            && toks[i + 5].is(TokKind::Punct, ")")
            && toks[i + 6].is(TokKind::Punct, "]");
        if !attr {
            i += 1;
            continue;
        }
        // Walk to the end of the attributed item: the matching `}` of
        // the first brace block, or a `;` before any brace opens.
        let mut j = i + 7;
        let mut depth = 0usize;
        let mut opened = false;
        while j < toks.len() {
            if toks[j].kind == TokKind::Punct {
                match toks[j].text.as_str() {
                    "{" => {
                        depth += 1;
                        opened = true;
                    }
                    "}" => {
                        depth = depth.saturating_sub(1);
                        if opened && depth == 0 {
                            break;
                        }
                    }
                    ";" if !opened => break,
                    _ => {}
                }
            }
            j += 1;
        }
        let end = j.min(toks.len().saturating_sub(1));
        for m in &mut mask[i..=end] {
            *m = true;
        }
        i = end + 1;
    }
    mask
}

/// Parse every `lint:allow(...)` comment in the file. Malformed allows
/// (no rule id / no reason) come back as findings in the second slot.
pub fn parse_suppressions(ctx: &FileCtx) -> (Vec<Suppression>, Vec<Finding>) {
    let mut sups = Vec::new();
    let mut bad = Vec::new();
    for t in &ctx.toks {
        if t.kind != TokKind::Comment {
            continue;
        }
        let Some(at) = t.text.find("lint:allow(") else { continue };
        let rest = &t.text[at + "lint:allow(".len()..];
        let Some(close) = rest.rfind(')') else {
            bad.push(Finding {
                rule: LINT_ALLOW,
                file: ctx.path.clone(),
                line: t.line,
                message: "malformed lint:allow — missing closing `)`".to_string(),
            });
            continue;
        };
        let body = &rest[..close];
        let (rule, reason) = match body.split_once(':') {
            Some((r, why)) => (r.trim(), why.trim()),
            None => (body.trim(), ""),
        };
        if rule.is_empty() || !ALL_RULES.contains(&rule) {
            bad.push(Finding {
                rule: LINT_ALLOW,
                file: ctx.path.clone(),
                line: t.line,
                message: format!("lint:allow names unknown rule {rule:?}"),
            });
            continue;
        }
        if reason.is_empty() {
            bad.push(Finding {
                rule: LINT_ALLOW,
                file: ctx.path.clone(),
                line: t.line,
                message: format!(
                    "lint:allow({rule}) requires a reason: `lint:allow({rule}: why)`"
                ),
            });
            continue;
        }
        sups.push(Suppression {
            rule: rule.to_string(),
            reason: reason.to_string(),
            line: t.line,
        });
    }
    (sups, bad)
}

/// Drop findings covered by a same-line or preceding-line suppression
/// for their rule.
fn apply_suppressions(findings: Vec<Finding>, sups: &[Suppression]) -> Vec<Finding> {
    findings
        .into_iter()
        .filter(|f| {
            !sups.iter().any(|s| {
                s.rule == f.rule && (s.line == f.line || s.line + 1 == f.line)
            })
        })
        .collect()
}

/// Accumulate `elapsed` onto `rule`'s row (creating it on first use).
fn timed<T>(
    times: &mut Vec<(&'static str, Duration)>,
    rule: &'static str,
    f: impl FnOnce() -> T,
) -> T {
    let t0 = std::time::Instant::now();
    let out = f();
    let d = t0.elapsed();
    match times.iter_mut().find(|(r, _)| *r == rule) {
        Some((_, total)) => *total += d,
        None => times.push((rule, d)),
    }
    out
}

/// Run every rule over the repository. `manifest_dir` is the `rust/`
/// crate root (`CARGO_MANIFEST_DIR`); API.md is resolved one level up.
pub fn run(manifest_dir: &Path) -> Vec<Finding> {
    run_timed(manifest_dir).0
}

/// [`run`], also returning per-rule wall time (report order) for the
/// binary's `--timing` flag — lint cost stays visible as rules grow.
pub fn run_timed(manifest_dir: &Path) -> (Vec<Finding>, Vec<(&'static str, Duration)>) {
    let src = manifest_dir.join("src");
    let repo = manifest_dir.parent().unwrap_or(manifest_dir);
    let mut times: Vec<(&'static str, Duration)> = Vec::new();
    let mut findings = Vec::new();
    let mut sources: Vec<(String, String)> = Vec::new();
    for dir in SCOPED_DIRS {
        let mut files = Vec::new();
        collect_rs_files(&src.join(dir), &mut files);
        files.sort();
        for path in files {
            let Ok(source) = std::fs::read_to_string(&path) else { continue };
            let rel = path
                .strip_prefix(repo)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let ctx = FileCtx::new(&rel, &source);
            let (sups, mut malformed) = parse_suppressions(&ctx);
            let mut found = Vec::new();
            found.extend(timed(&mut times, WALL_CLOCK, || rules::wall_clock::check(&ctx)));
            found.extend(timed(&mut times, NAKED_CONDVAR_WAIT, || {
                rules::condvar_wait::check(&ctx)
            }));
            found.extend(timed(&mut times, POISONED_LOCK_UNWRAP, || {
                rules::poison_lock::check(&ctx)
            }));
            let mut out = apply_suppressions(found, &sups);
            out.append(&mut malformed);
            findings.extend(out);
            sources.push((rel, source));
        }
    }
    findings.extend(check_program_inner(&sources, true, &mut times));
    findings.extend(timed(&mut times, STATS_DOC_DRIFT, || {
        rules::stats_doc::check_repo(manifest_dir)
    }));
    findings.extend(timed(&mut times, CONFIG_DOC_DRIFT, || {
        rules::config_doc::check_repo(manifest_dir)
    }));
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    (findings, times)
}

/// Run the per-file token rules plus suppression handling over one
/// file's source. Public for the fixture tests.
pub fn check_source(path: &str, source: &str) -> Vec<Finding> {
    let ctx = FileCtx::new(path, source);
    let (sups, mut malformed) = parse_suppressions(&ctx);
    let mut found = Vec::new();
    found.extend(rules::wall_clock::check(&ctx));
    found.extend(rules::condvar_wait::check(&ctx));
    found.extend(rules::poison_lock::check(&ctx));
    let mut out = apply_suppressions(found, &sups);
    out.append(&mut malformed);
    out
}

/// Run the whole-program rules over an explicit `(path, source)` set.
/// Public for the fixture tests; staleness runs in partial mode (a
/// declared lock site is only judged when its file is in the set).
/// Suppressions apply; malformed-allow findings are NOT emitted here
/// (the per-file pass owns those, so they never double-report).
pub fn check_program(files: &[(String, String)]) -> Vec<Finding> {
    let mut times = Vec::new();
    check_program_inner(files, false, &mut times)
}

fn check_program_inner(
    files: &[(String, String)],
    complete_staleness: bool,
    times: &mut Vec<(&'static str, Duration)>,
) -> Vec<Finding> {
    let (program, sums) = timed(times, SUMMARIES_PHASE, || {
        let p = symbols::Program::build(files);
        let s = summaries::compute(&p);
        (p, s)
    });
    let mut found = timed(times, GLOBAL_LOCK_ORDER, || {
        rules::lock_order::check(&program, &sums, complete_staleness)
    });
    found.extend(timed(times, BLOCKING_UNDER_LOCK, || {
        rules::blocking_under_lock::check(&program, &sums)
    }));
    let sups_by_file: BTreeMap<&str, Vec<Suppression>> = program
        .files
        .iter()
        .map(|fs| (fs.ctx.path.as_str(), parse_suppressions(&fs.ctx).0))
        .collect();
    found
        .into_iter()
        .filter(|f| {
            let Some(sups) = sups_by_file.get(f.file.as_str()) else { return true };
            !sups.iter().any(|s| {
                s.rule == f.rule && (s.line == f.line || s.line + 1 == f.line)
            })
        })
        .collect()
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// THE tier-1 gate: the tree must be lint-clean — now including
    /// the whole-program rules. Reverting any of the PR's fixes (e.g.
    /// the Drop impls back to joining worker threads while holding
    /// their handle list's mutex) makes this test fail.
    #[test]
    fn repo_tree_is_lint_clean() {
        let manifest_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = run(manifest_dir);
        assert!(
            findings.is_empty(),
            "pallas-lint found {} violation(s):\n{}",
            findings.len(),
            findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
        );
    }

    /// Timing rows cover every phase that ran, so `--timing` output
    /// cannot silently omit a rule as the suite grows.
    #[test]
    fn run_timed_reports_every_phase() {
        let manifest_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
        let (_, times) = run_timed(manifest_dir);
        for rule in [
            WALL_CLOCK,
            NAKED_CONDVAR_WAIT,
            POISONED_LOCK_UNWRAP,
            SUMMARIES_PHASE,
            GLOBAL_LOCK_ORDER,
            BLOCKING_UNDER_LOCK,
            STATS_DOC_DRIFT,
            CONFIG_DOC_DRIFT,
        ] {
            assert!(times.iter().any(|(r, _)| *r == rule), "no timing row for {rule}");
        }
    }

    #[test]
    fn cfg_test_region_masks_mod_tests() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn inner() {}\n}\nfn after() {}\n";
        let ctx = FileCtx::new("x.rs", src);
        let live = ctx.toks.iter().position(|t| t.is(TokKind::Ident, "live")).unwrap();
        let inner = ctx.toks.iter().position(|t| t.is(TokKind::Ident, "inner")).unwrap();
        let after = ctx.toks.iter().position(|t| t.is(TokKind::Ident, "after")).unwrap();
        assert!(!ctx.is_test[live]);
        assert!(ctx.is_test[inner]);
        assert!(!ctx.is_test[after], "masking ends at the matching brace");
    }

    #[test]
    fn cfg_test_on_braceless_item_ends_at_semicolon() {
        let src = "#[cfg(test)]\nuse std::time::Instant;\nfn live() {}\n";
        let ctx = FileCtx::new("x.rs", src);
        let live = ctx.toks.iter().position(|t| t.is(TokKind::Ident, "live")).unwrap();
        assert!(!ctx.is_test[live]);
    }

    #[test]
    fn suppression_requires_reason() {
        let src = "// lint:allow(wall-clock)\nfn f() {}\n";
        let ctx = FileCtx::new("x.rs", src);
        let (sups, bad) = parse_suppressions(&ctx);
        assert!(sups.is_empty());
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].rule, LINT_ALLOW);
        assert!(bad[0].message.contains("requires a reason"), "{}", bad[0].message);
    }

    #[test]
    fn suppression_with_reason_parses_and_suppresses_next_line() {
        let src = "// lint:allow(wall-clock: measuring real engine work)\nlet t = Instant::now();\n";
        let ctx = FileCtx::new("platform/x.rs", src);
        let (sups, bad) = parse_suppressions(&ctx);
        assert!(bad.is_empty());
        assert_eq!(sups.len(), 1);
        assert_eq!(sups[0].rule, "wall-clock");
        assert_eq!(sups[0].reason, "measuring real engine work");
        assert!(check_source("platform/x.rs", src).is_empty(), "finding suppressed");
    }

    #[test]
    fn suppression_for_unknown_rule_is_a_finding() {
        let src = "// lint:allow(made-up-rule: because)\nfn f() {}\n";
        let (sups, bad) = parse_suppressions(&FileCtx::new("x.rs", src));
        assert!(sups.is_empty());
        assert_eq!(bad.len(), 1);
        assert!(bad[0].message.contains("unknown rule"));
    }

    #[test]
    fn suppression_does_not_leak_to_other_rules_or_lines() {
        let src = "// lint:allow(wall-clock: only this rule)\nfn f() { x.lock().unwrap(); }\n";
        let out = check_source("platform/x.rs", src);
        assert_eq!(out.len(), 1, "poisoned-lock-unwrap still fires: {out:?}");
        assert_eq!(out[0].rule, POISONED_LOCK_UNWRAP);
        // Two lines below the allow: out of its reach.
        let src = "// lint:allow(wall-clock: too far away)\n\nlet t = Instant::now();\n";
        let out = check_source("platform/x.rs", src);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, WALL_CLOCK);
    }
}
