//! Rule `naked-condvar-wait`: every condvar wait must be bounded.
//!
//! A bare `Condvar::wait(guard)` parks forever on a missed wakeup — a
//! notifier that crashes between its state write and its `notify`, or
//! a poisoned-mutex unwind, strands the waiter permanently. The
//! platform's waiting idiom is a predicate loop around a *bounded*
//! wait (`pwait_timeout` with a generation counter or re-checked
//! phase), where a lost notify costs one slice, not liveness.
//!
//! Token shape: `.wait(<something>)` — a wait that consumes a guard
//! argument. Argument-less `.wait()` calls (e.g. `BatchMember::wait`,
//! thread joins) are domain methods, not condvar waits.

use crate::lints::tokenizer::TokKind;
use crate::lints::{FileCtx, Finding, NAKED_CONDVAR_WAIT};

pub fn check(ctx: &FileCtx) -> Vec<Finding> {
    let toks = &ctx.toks;
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if ctx.is_test[i] {
            continue;
        }
        // `.` `wait` `(` <non-")"> …
        if i + 3 < toks.len()
            && toks[i].is(TokKind::Punct, ".")
            && toks[i + 1].is(TokKind::Ident, "wait")
            && toks[i + 2].is(TokKind::Punct, "(")
            && !toks[i + 3].is(TokKind::Punct, ")")
        {
            out.push(Finding {
                rule: NAKED_CONDVAR_WAIT,
                file: ctx.path.clone(),
                line: toks[i + 1].line,
                message: "unbounded condvar wait — park in bounded slices \
                          (util::sync::pwait_timeout) inside a predicate loop so a missed \
                          notify can never strand the waiter"
                    .to_string(),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(src: &str) -> Vec<Finding> {
        check(&FileCtx::new("platform/fixture.rs", src))
    }

    #[test]
    fn flags_guard_consuming_wait() {
        let hits = lint("fn f() { queue = shared.cv.wait(queue).unwrap(); }\n");
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, NAKED_CONDVAR_WAIT);
    }

    #[test]
    fn argless_wait_is_a_domain_method() {
        assert!(lint("fn f() { let share = member.wait()?; handle.wait(); }\n").is_empty());
    }

    #[test]
    fn wait_timeout_is_fine() {
        assert!(lint("fn f() { let (g, _) = cv.wait_timeout(g, d).unwrap(); }\n").is_empty());
        assert!(lint("fn f() { let (g, _) = pwait_timeout(&cv, g, d); }\n").is_empty());
    }

    #[test]
    fn test_code_may_wait_naked() {
        assert!(lint("#[cfg(test)]\nmod tests {\n fn t() { cv.wait(g).unwrap(); }\n}\n").is_empty());
    }
}
