//! The rule implementations. Each rule exposes
//! `check(&FileCtx) -> Vec<Finding>` (rule 5, `stats_doc`, checks the
//! stats route source against API.md instead and exposes
//! `check_repo`).

pub mod condvar_wait;
pub mod lock_order;
pub mod poison_lock;
pub mod stats_doc;
pub mod wall_clock;

use super::tokenizer::{Tok, TokKind};

/// True when `toks[i..]` starts with the given `(kind, text)` pattern.
pub(crate) fn matches_seq(toks: &[Tok], i: usize, pat: &[(TokKind, &str)]) -> bool {
    if i + pat.len() > toks.len() {
        return false;
    }
    pat.iter().enumerate().all(|(k, (kind, text))| toks[i + k].is(*kind, text))
}

/// Parse a field path ending at `toks[end]` (exclusive), walking
/// backwards over `ident (. ident)*` — e.g. for the tokens of
/// `self.shared.queue` returns `["self", "shared", "queue"]`. Returns
/// an empty vec when `toks[end-1]` is not an identifier.
pub(crate) fn path_before(toks: &[Tok], end: usize) -> Vec<String> {
    let mut segs: Vec<String> = Vec::new();
    let mut i = end;
    loop {
        if i == 0 || toks[i - 1].kind != TokKind::Ident {
            break;
        }
        segs.push(toks[i - 1].text.clone());
        i -= 1;
        if i == 0 || !toks[i - 1].is(TokKind::Punct, ".") {
            break;
        }
        i -= 1;
    }
    segs.reverse();
    segs
}
