//! The rule implementations. Per-file rules expose
//! `check(&FileCtx) -> Vec<Finding>`; the whole-program rules
//! (`lock_order`, `blocking_under_lock`) run over the computed
//! [`crate::lints::summaries::Summaries`] instead, and the doc-drift
//! rules (`stats_doc`, `config_doc`) expose `check_repo`.

pub mod blocking_under_lock;
pub mod condvar_wait;
pub mod config_doc;
pub mod lock_order;
pub mod poison_lock;
pub mod stats_doc;
pub mod wall_clock;

use super::tokenizer::{Tok, TokKind};

/// True when `toks[i..]` starts with the given `(kind, text)` pattern.
pub(crate) fn matches_seq(toks: &[Tok], i: usize, pat: &[(TokKind, &str)]) -> bool {
    if i + pat.len() > toks.len() {
        return false;
    }
    pat.iter().enumerate().all(|(k, (kind, text))| toks[i + k].is(*kind, text))
}
