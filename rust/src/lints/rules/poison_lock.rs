//! Rule `poisoned-lock-unwrap`: Mutex acquisition must tolerate
//! poison.
//!
//! Every platform mutex protects plain data whose invariants hold
//! between statements — poison after a panicking holder is noise, not
//! corruption. `.lock().unwrap()` turns one panicking request thread
//! into a platform-wide cascade: the batcher's state, the warm pool,
//! the async queue all become landmines that panic every later
//! toucher. The shared idiom is [`crate::util::plock`] (and
//! `pwait_timeout` for condvar waits), which maps `PoisonError` to
//! its inner guard.

use crate::lints::tokenizer::TokKind;
use crate::lints::{FileCtx, Finding, POISONED_LOCK_UNWRAP};

pub fn check(ctx: &FileCtx) -> Vec<Finding> {
    let toks = &ctx.toks;
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if ctx.is_test[i] {
            continue;
        }
        // `.` `lock` `(` `)` `.` (`unwrap`|`expect`) `(`
        if i + 6 < toks.len()
            && toks[i].is(TokKind::Punct, ".")
            && toks[i + 1].is(TokKind::Ident, "lock")
            && toks[i + 2].is(TokKind::Punct, "(")
            && toks[i + 3].is(TokKind::Punct, ")")
            && toks[i + 4].is(TokKind::Punct, ".")
            && (toks[i + 5].is(TokKind::Ident, "unwrap") || toks[i + 5].is(TokKind::Ident, "expect"))
            && toks[i + 6].is(TokKind::Punct, "(")
        {
            out.push(Finding {
                rule: POISONED_LOCK_UNWRAP,
                file: ctx.path.clone(),
                line: toks[i + 1].line,
                message: format!(
                    ".lock().{}() panics on a poisoned mutex, cascading one panicking \
                     holder into every later toucher — use util::sync::plock, which maps \
                     PoisonError to its inner guard",
                    toks[i + 5].text
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(src: &str) -> Vec<Finding> {
        check(&FileCtx::new("platform/fixture.rs", src))
    }

    #[test]
    fn flags_unwrap_and_expect() {
        let hits = lint(
            "fn f() {\n    let a = self.idle.lock().unwrap();\n    let b = m.lock().expect(\"poisoned\");\n}\n",
        );
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].line, 2);
        assert!(hits[1].message.contains("expect"));
    }

    #[test]
    fn plock_is_the_fix() {
        assert!(lint("fn f() { let g = plock(&self.idle); }\n").is_empty());
    }

    #[test]
    fn unwrap_of_non_lock_results_is_fine() {
        assert!(lint("fn f() { reg.get(name).unwrap(); cv.wait_timeout(g, d).unwrap(); }\n").is_empty());
    }

    #[test]
    fn test_code_may_unwrap() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { s.inner.lock().unwrap(); }\n}\n";
        assert!(lint(src).is_empty());
    }
}
