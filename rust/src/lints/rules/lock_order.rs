//! Rule `global-lock-order`: every tracked platform mutex has one
//! global rank, and every acquisition path — within a function or
//! across the call graph — must respect it.
//!
//! [`PLATFORM_LOCK_ORDER`] replaces the old per-file `MANIFEST`: a
//! single declared rank order for all platform/runtime locks. Rank is
//! table position; a lock may be held while acquiring a *later*
//! (higher-rank) one, never the reverse. The sanctioned nestings
//! today are the batcher (`open` held while probing a batch's
//! `inner`) and the async invoker (`queue` held while seeding
//! `results`, and held across the registry read that sizes a
//! pre-formed drain); all run outermost-first under the declared
//! order. Everything else is single-lock by design, and this rule
//! keeps it that way across refactors that smear a deadlock over two
//! individually-clean files.
//!
//! Four findings, all from the [`Summaries`] event stream:
//!
//! - **re-entry** — acquiring a lock already held (self-deadlock with
//!   `std::sync::Mutex`), directly or via a callee whose transitive
//!   summary re-acquires it;
//! - **rank inversion** — acquiring a lower-ranked lock while a
//!   higher-ranked one is held, directly or interprocedurally (the
//!   finding prints the witness chain through the call graph);
//! - **cycle** — a loop in the observed acquired-while-holding graph,
//!   reported even if some edge pairs individually dodge the rank
//!   check (belt and braces: the ranks make cycles impossible, so a
//!   cycle means the table itself was edited into inconsistency);
//! - **staleness** — a declared site naming a mutex field that no
//!   longer exists in its file, so the table cannot rot as code moves.

use crate::lints::summaries::{EventKind, Summaries};
use crate::lints::symbols::Program;
use crate::lints::{Finding, GLOBAL_LOCK_ORDER};
use std::collections::{BTreeMap, BTreeSet};

/// One declared lock: display name and the `(file-suffix, field-name)`
/// sites that constitute it. `rwlock` sites are tracked through
/// zero-arg `.read()`/`.write()` instead of `plock`/`.lock()`.
pub struct LockDecl {
    pub name: &'static str,
    pub sites: &'static [(&'static str, &'static str)],
    pub rwlock: bool,
}

const fn decl(
    name: &'static str,
    sites: &'static [(&'static str, &'static str)],
    rwlock: bool,
) -> LockDecl {
    LockDecl { name, sites, rwlock }
}

/// THE global lock rank order, outermost first. Position is rank: a
/// lock may be held while acquiring any lock *below* it in this table.
/// Adding a platform mutex means inserting it here at the rank its
/// callers need — and the staleness check fails CI if a renamed or
/// deleted field leaves its row behind.
pub const PLATFORM_LOCK_ORDER: &[LockDecl] = &[
    decl("invoker.maintainer", &[("platform/invoker.rs", "maintainer")], false),
    decl(
        "invoker.fn_in_flight",
        &[("platform/invoker.rs", "fn_in_flight"), ("platform/invoker.rs", "map")],
        false,
    ),
    decl("dispatcher.depth_by_fn", &[("platform/dispatcher.rs", "depth_by_fn")], false),
    decl("batcher.open", &[("platform/batcher.rs", "open")], false),
    decl("batcher.inner", &[("platform/batcher.rs", "inner")], false),
    decl("async_invoke.queue", &[("platform/async_invoke.rs", "queue")], false),
    decl("async_invoke.results", &[("platform/async_invoke.rs", "results")], false),
    decl("async_invoke.workers", &[("platform/async_invoke.rs", "workers")], false),
    decl("maintainer.stop", &[("platform/maintainer.rs", "stop")], false),
    // `idle`/`waiters` live on `PoolShard` (one instance per hash
    // bucket). A rank covers every shard instance of the field, which
    // is strictly stronger than per-instance ordering: pool code may
    // hold at most ONE shard's `idle` (sweeps iterate shards
    // sequentially, dropping each guard before the next), so holding
    // rank 9 while taking rank 9 on a sibling shard would correctly
    // flag as re-entry. `idle` before `waiters` because release paths
    // update a shard's map, drop the guard, then bump+signal that
    // shard's generation.
    decl("pool.idle", &[("platform/pool.rs", "idle")], false),
    decl("pool.waiters", &[("platform/pool.rs", "waiters")], false),
    decl("registry.functions", &[("platform/registry.rs", "functions")], true),
    decl("snapshots.inner", &[("platform/snapshots.rs", "inner")], false),
    // Adaptive-controller state. Ranked just above the metrics shards:
    // the policy map is only ever taken standalone (arrival updates,
    // window/rung/forecast reads after any flight-tracking or queue
    // lock has been released), and nothing may call back into the
    // invoker while holding it.
    decl("policy.state", &[("platform/policy.rs", "state")], false),
    decl("metrics.shards", &[("platform/metrics.rs", "shards")], true),
    decl("metrics.totals", &[("platform/metrics.rs", "totals")], false),
    decl("metrics.recent", &[("platform/metrics.rs", "recent")], false),
    decl("billing.lines", &[("platform/billing.rs", "lines")], false),
    decl(
        "platform.rng",
        &[
            ("platform/invoker.rs", "rng"),
            ("platform/scaler.rs", "rng"),
            ("platform/trace.rs", "rng"),
        ],
        false,
    ),
    // Trace exemplar ring. Taken standalone after the metrics record
    // and the policy feed have both returned, and the sampling rng
    // guard is drawn and dropped before the ring is touched — so the
    // ring ranks below every hot-path lock and nothing may call back
    // into the platform while holding it.
    decl("trace.ring", &[("platform/trace.rs", "ring")], false),
    decl("mock.compiled", &[("runtime/mock.rs", "compiled")], false),
    // Batch-N kernel ladder cache. Ranked between the model cache and
    // the instance map: a batched flush reads `instances` (liveness)
    // after updating the ladder, and nothing holds `compiled_batch`
    // while touching `compiled`.
    decl("mock.compiled_batch", &[("runtime/mock.rs", "compiled_batch")], false),
    decl("mock.instances", &[("runtime/mock.rs", "instances")], false),
    decl("pjrt.joins", &[("runtime/pjrt.rs", "joins")], false),
];

/// Rank of the lock named `path::name`, or `None` when untracked.
pub fn lock_for(path: &str, name: &str) -> Option<usize> {
    PLATFORM_LOCK_ORDER.iter().position(|d| {
        d.sites.iter().any(|(suf, local)| path.ends_with(suf) && *local == name)
    })
}

/// Is `path::name` a declared RwLock site (tracked via `.read()` /
/// `.write()`)?
pub fn is_rw_site(path: &str, name: &str) -> bool {
    PLATFORM_LOCK_ORDER.iter().any(|d| {
        d.rwlock && d.sites.iter().any(|(suf, local)| path.ends_with(suf) && *local == name)
    })
}

/// Display name of rank `lid`.
pub fn name_of(lid: usize) -> &'static str {
    PLATFORM_LOCK_ORDER[lid].name
}

/// Rank of a lock by display name — test/diagnostic convenience.
pub fn rank_of(name: &str) -> usize {
    PLATFORM_LOCK_ORDER.iter().position(|d| d.name == name).expect("declared lock")
}

/// Run the rule over the computed summaries. `complete_staleness`
/// demands every declared site exist (the repo run); fixtures pass
/// `false` so a partial file set only vouches for the files it has.
pub fn check(p: &Program, s: &Summaries, complete_staleness: bool) -> Vec<Finding> {
    let mut out = Vec::new();
    // Observed acquired-while-holding edges, for cycle detection.
    let mut nest: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
    for (idx, evs) in s.events.iter().enumerate() {
        let path = &p.files[p.fns[idx].file].ctx.path;
        for e in evs {
            match &e.kind {
                EventKind::Acquire(lid) => {
                    for h in &e.held {
                        nest.entry(h.lock).or_default().insert(*lid);
                        if h.lock == *lid {
                            out.push(Finding {
                                rule: GLOBAL_LOCK_ORDER,
                                file: path.clone(),
                                line: e.line,
                                message: format!(
                                    "re-enters `{}` already held (taken at line {}) — \
                                     self-deadlock",
                                    name_of(*lid),
                                    h.line
                                ),
                            });
                        } else if *lid < h.lock {
                            out.push(Finding {
                                rule: GLOBAL_LOCK_ORDER,
                                file: path.clone(),
                                line: e.line,
                                message: format!(
                                    "acquires `{}` (rank {}) while holding `{}` (rank {}) — \
                                     the global order is outermost-first; see \
                                     PLATFORM_LOCK_ORDER",
                                    name_of(*lid),
                                    lid,
                                    name_of(h.lock),
                                    h.lock
                                ),
                            });
                        }
                    }
                }
                EventKind::Call { name, cands } if !e.held.is_empty() => {
                    let mut tacq: BTreeSet<usize> = BTreeSet::new();
                    for &c in cands {
                        tacq.extend(s.acquires[c].iter().copied());
                    }
                    for h in &e.held {
                        for &lid in &tacq {
                            nest.entry(h.lock).or_default().insert(lid);
                            let witness = || {
                                cands
                                    .iter()
                                    .find(|&&c| s.acquires[c].contains(&lid))
                                    .map(|&c| s.acquire_chain(p, c, lid))
                                    .unwrap_or_default()
                            };
                            if lid == h.lock {
                                out.push(Finding {
                                    rule: GLOBAL_LOCK_ORDER,
                                    file: path.clone(),
                                    line: e.line,
                                    message: format!(
                                        "calls `{name}` which (transitively) re-acquires held \
                                         `{}` [{}]",
                                        name_of(lid),
                                        witness()
                                    ),
                                });
                            } else if lid < h.lock {
                                out.push(Finding {
                                    rule: GLOBAL_LOCK_ORDER,
                                    file: path.clone(),
                                    line: e.line,
                                    message: format!(
                                        "calls `{name}` which acquires `{}` (rank {}) while \
                                         `{}` (rank {}) is held [{}]",
                                        name_of(lid),
                                        lid,
                                        name_of(h.lock),
                                        h.lock,
                                        witness()
                                    ),
                                });
                            }
                        }
                    }
                }
                _ => {}
            }
        }
    }
    out.extend(find_cycles(&nest));
    out.extend(staleness(p, complete_staleness));
    out
}

/// DFS over the observed acquired-while-holding edges; any back edge
/// is a reportable cycle.
fn find_cycles(edges: &BTreeMap<usize, BTreeSet<usize>>) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut color: BTreeMap<usize, u8> = BTreeMap::new();
    fn dfs(
        u: usize,
        stack: &mut Vec<usize>,
        edges: &BTreeMap<usize, BTreeSet<usize>>,
        color: &mut BTreeMap<usize, u8>,
        out: &mut Vec<Finding>,
    ) {
        color.insert(u, 1);
        if let Some(next) = edges.get(&u) {
            for &v in next {
                match color.get(&v).copied().unwrap_or(0) {
                    1 => {
                        let from = stack.iter().position(|&x| x == v).unwrap_or(0);
                        let mut cyc: Vec<&str> =
                            stack[from..].iter().map(|&x| name_of(x)).collect();
                        cyc.push(name_of(v));
                        out.push(Finding {
                            rule: GLOBAL_LOCK_ORDER,
                            file: "(global)".to_string(),
                            line: 0,
                            message: format!("lock cycle: {}", cyc.join(" -> ")),
                        });
                    }
                    0 => {
                        stack.push(v);
                        dfs(v, stack, edges, color, out);
                        stack.pop();
                    }
                    _ => {}
                }
            }
        }
        color.insert(u, 2);
    }
    for &u in edges.keys() {
        if color.get(&u).copied().unwrap_or(0) == 0 {
            let mut stack = vec![u];
            dfs(u, &mut stack, edges, &mut color, &mut out);
        }
    }
    out
}

/// Every declared site must name a `Mutex`/`RwLock` field (or fn
/// param) that still exists in its file. In partial mode, sites whose
/// file is absent from the analyzed set are skipped.
fn staleness(p: &Program, complete: bool) -> Vec<Finding> {
    // path -> lock-ish field and param names present there.
    let mut lockish: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for fs in &p.files {
        let entry = lockish.entry(fs.ctx.path.as_str()).or_default();
        for fields in fs.structs.values() {
            for (fname, info) in fields {
                if info.is_mutex || info.is_rwlock {
                    entry.insert(fname.as_str());
                }
            }
        }
    }
    for fd in &p.fns {
        let entry = lockish.entry(p.files[fd.file].ctx.path.as_str()).or_default();
        for (pname, info) in &fd.params {
            if info.is_mutex || info.is_rwlock {
                entry.insert(pname.as_str());
            }
        }
    }
    let mut out = Vec::new();
    for d in PLATFORM_LOCK_ORDER {
        for (suf, local) in d.sites {
            let mut file_seen = false;
            let mut hit = false;
            for (path, names) in &lockish {
                if path.ends_with(suf) {
                    file_seen = true;
                    if names.contains(local) {
                        hit = true;
                    }
                }
            }
            if hit || (!complete && !file_seen) {
                continue;
            }
            out.push(Finding {
                rule: GLOBAL_LOCK_ORDER,
                file: suf.to_string(),
                line: 0,
                message: format!(
                    "declared lock `{}` names `{local}` which no longer exists in {suf} — \
                     update PLATFORM_LOCK_ORDER",
                    d.name
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lints::check_program;

    fn run(files: &[(&str, &str)]) -> Vec<Finding> {
        let owned: Vec<(String, String)> =
            files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect();
        check_program(&owned)
    }

    fn has(f: &[Finding], rule: &str, substr: &str) -> bool {
        f.iter().any(|x| x.rule == rule && x.message.contains(substr))
    }

    #[test]
    fn table_ranks_are_consistent() {
        assert!(rank_of("batcher.open") < rank_of("batcher.inner"));
        assert!(rank_of("async_invoke.queue") < rank_of("async_invoke.results"));
    }

    #[test]
    fn policy_state_ranks_between_snapshots_and_metrics() {
        assert!(rank_of("snapshots.inner") < rank_of("policy.state"));
        assert!(rank_of("policy.state") < rank_of("metrics.shards"));
        // Holding a metrics shard while consulting the policy map is an
        // inversion: controllers read telemetry AFTER the sink's locks
        // are released, never under them.
        let metrics_src = "pub struct FnMetricsSink { shards: RwLock<u32>, totals: Mutex<u32>, recent: Mutex<u32>, p: PolicyEngine }\nimpl FnMetricsSink {\n    fn f(&self) {\n        let g = self.shards.read();\n        self.p.probe(name);\n    }\n    pub fn observe(&self) {\n        let g = self.shards.read();\n    }\n}\n";
        let f = run(&[
            ("rust/src/platform/metrics.rs", metrics_src),
            (
                "rust/src/platform/policy.rs",
                "pub struct PolicyEngine { state: Mutex<u32> }\nimpl PolicyEngine {\n    pub fn probe(&self, name: &str) {\n        let s = plock(&self.state);\n    }\n}\n",
            ),
        ]);
        assert!(has(&f, GLOBAL_LOCK_ORDER, "policy.state"), "{f:?}");
        assert!(has(&f, GLOBAL_LOCK_ORDER, "probe"), "witness names the callee: {f:?}");
        // The sanctioned direction — policy.state held while calling
        // into a later-ranked metrics lock — is clean.
        let ok = run(&[
            ("rust/src/platform/metrics.rs", metrics_src),
            (
                "rust/src/platform/policy.rs",
                "pub struct PolicyEngine { state: Mutex<u32>, m: FnMetricsSink }\nimpl PolicyEngine {\n    fn f(&self) {\n        let s = plock(&self.state);\n        self.m.observe();\n    }\n}\n",
            ),
        ]);
        assert!(!ok.iter().any(|x| x.rule == GLOBAL_LOCK_ORDER), "{ok:?}");
    }

    #[test]
    fn trace_ring_ranks_last_among_platform_locks() {
        assert!(rank_of("platform.rng") < rank_of("trace.ring"));
        assert!(rank_of("metrics.totals") < rank_of("trace.ring"));
        // Holding the exemplar ring while calling back into the
        // metrics sink is an inversion: traces are finished strictly
        // AFTER the metrics record has been committed and released.
        let trace_src = "pub struct TraceSink { ring: Mutex<u32>, m: FnMetricsSink }\nimpl TraceSink {\n    fn f(&self) {\n        let g = plock(&self.ring);\n        self.m.tally(name);\n    }\n}\n";
        let f = run(&[
            ("rust/src/platform/trace.rs", trace_src),
            (
                "rust/src/platform/metrics.rs",
                "pub struct FnMetricsSink { totals: Mutex<u32> }\nimpl FnMetricsSink {\n    pub fn tally(&self, name: &str) {\n        let t = plock(&self.totals);\n    }\n}\n",
            ),
        ]);
        assert!(has(&f, GLOBAL_LOCK_ORDER, "metrics.totals"), "{f:?}");
        // The sanctioned shape — rng coin drawn and dropped, then the
        // ring taken standalone — is clean.
        let ok = run(&[(
            "rust/src/platform/trace.rs",
            "pub struct TraceSink { rng: Mutex<u32>, ring: Mutex<u32> }\nimpl TraceSink {\n    fn finish(&self) {\n        let keep = { let r = plock(&self.rng); true };\n        if keep {\n            let g = plock(&self.ring);\n        }\n    }\n}\n",
        )]);
        assert!(!ok.iter().any(|x| x.rule == GLOBAL_LOCK_ORDER), "{ok:?}");
    }

    #[test]
    fn cross_file_inversion_is_flagged() {
        // pool.rs holds `idle` (rank 9) and calls a batcher method that
        // acquires `open` (rank 3) — clean per file, deadlock-shaped
        // globally.
        let f = run(&[
            (
                "rust/src/platform/pool.rs",
                "pub struct WarmPool { idle: Mutex<u32>, b: Batcher }\nimpl WarmPool {\n    fn f(&self) {\n        let g = plock(&self.idle);\n        self.b.grab(name);\n    }\n}\n",
            ),
            (
                "rust/src/platform/batcher.rs",
                "pub struct Batcher { open: Mutex<u32> }\nimpl Batcher {\n    pub fn grab(&self, name: &str) {\n        let o = plock(&self.open);\n    }\n}\n",
            ),
        ]);
        assert!(has(&f, GLOBAL_LOCK_ORDER, "batcher.open"), "{f:?}");
        assert!(has(&f, GLOBAL_LOCK_ORDER, "grab"), "witness names the callee: {f:?}");
    }

    #[test]
    fn interprocedural_reentry_is_flagged() {
        let f = run(&[(
            "rust/src/platform/pool.rs",
            "pub struct WarmPool { idle: Mutex<u32> }\nimpl WarmPool {\n    fn outer(&self) {\n        let g = plock(&self.idle);\n        self.inner_probe();\n    }\n    fn inner_probe(&self) {\n        let n = plock(&self.idle);\n    }\n}\n",
        )]);
        assert!(has(&f, GLOBAL_LOCK_ORDER, "re-acquires held"), "{f:?}");
    }

    #[test]
    fn mutual_recursion_reaches_fixpoint_and_stays_precise() {
        // Legal: hold `open` (rank 3), recursion briefly takes `inner`
        // (rank 4) in an inner block — outermost-first, clean.
        let legal = "pub struct Batcher { open: Mutex<u32>, inner: Mutex<u32> }\nimpl Batcher {\n    fn ping(&self, n: u32) { if n > 0 { self.pong(n); } }\n    fn pong(&self, n: u32) { { let g = plock(&self.inner); } self.ping(n - 1); }\n    fn top(&self) {\n        let o = plock(&self.open);\n        self.ping(3);\n    }\n}\n";
        let f = run(&[("rust/src/platform/batcher.rs", legal)]);
        assert!(!f.iter().any(|x| x.rule == GLOBAL_LOCK_ORDER), "{f:?}");
        // Inverted: hold `inner`, recursion takes `open` — flagged.
        let inverted = legal.replace("plock(&self.inner); }", "plock(&self.open); }").replace(
            "let o = plock(&self.open);",
            "let o = plock(&self.inner);",
        );
        let f = run(&[("rust/src/platform/batcher.rs", &inverted)]);
        assert!(has(&f, GLOBAL_LOCK_ORDER, "batcher.open"), "{f:?}");
    }

    #[test]
    fn cycles_in_the_nest_graph_are_named() {
        let f = run(&[(
            "rust/src/platform/batcher.rs",
            "pub struct Batcher { open: Mutex<u32>, inner: Mutex<u32> }\nimpl Batcher {\n    fn ab(&self) { let a = plock(&self.open); let b = plock(&self.inner); }\n    fn ba(&self) { let b = plock(&self.inner); let a = plock(&self.open); }\n}\n",
        )]);
        assert!(has(&f, GLOBAL_LOCK_ORDER, "lock cycle"), "{f:?}");
    }

    #[test]
    fn stale_declared_site_is_a_finding() {
        // pool.rs is present but `idle` was renamed away.
        let f = run(&[(
            "rust/src/platform/pool.rs",
            "pub struct WarmPool { idle_q: Mutex<u32> }\nimpl WarmPool {\n    fn f(&self) {}\n}\n",
        )]);
        assert!(has(&f, GLOBAL_LOCK_ORDER, "no longer exists"), "{f:?}");
        // Partial mode: absent files are not judged.
        assert!(
            !f.iter().any(|x| x.message.contains("batcher")),
            "absent files vouch for nothing: {f:?}"
        );
    }

    #[test]
    fn lint_allow_suppresses_global_lock_order() {
        let f = run(&[(
            "rust/src/platform/batcher.rs",
            "pub struct Batcher { open: Mutex<u32>, inner: Mutex<u32> }\nimpl Batcher {\n    fn f(&self) {\n        let b = plock(&self.inner);\n        // lint:allow(global-lock-order: fixture proves suppression plumbing)\n        let a = plock(&self.open);\n    }\n}\n",
        )]);
        assert!(!f.iter().any(|x| x.rule == GLOBAL_LOCK_ORDER), "{f:?}");
    }
}
