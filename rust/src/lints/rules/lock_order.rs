//! Rule `lock-order`: nested acquisitions of a file's declared locks
//! must follow the manifest order, never re-enter a held lock, and
//! never sit across a condvar wait alongside a second lock.
//!
//! [`MANIFEST`] is the repo's lock-ordering declaration: for each file
//! owning more than zero platform mutexes, the order in which they may
//! be nested (earlier may be held while acquiring later — never the
//! reverse). The two real nestings today are the batcher (`open`, the
//! function→batch map, held while probing a batch's `inner`) and the
//! async invoker (`queue` held while seeding `results` in `submit`).
//! Everything else is single-lock by design, and this rule keeps it
//! that way: an innocent-looking "grab the other map too" refactor
//! fails the lint instead of deadlocking a soak test three weeks
//! later.
//!
//! The analysis is intra-function and token-level, with deliberately
//! conservative guard-liveness tracking:
//!
//! - a `let`-bound guard lives until `drop(name)` or its block closes;
//! - a temporary guard (`plock(&x).field`, `if let … = plock(&x)…`)
//!   lives to the end of its statement — the `;`, or the `}` of an
//!   attached block (matching Rust's real temporary-scope rules for
//!   `match`/`if let`, which extend the guard across the whole arm);
//! - acquisitions through a computed receiver (`self.shard(f)`) are
//!   untracked: those are leaf locks keyed per function, not part of
//!   any ordering relation.

use crate::lints::tokenizer::{Tok, TokKind};
use crate::lints::{FileCtx, Finding, LOCK_ORDER};

use super::path_before;

/// The declared lock order per file (path suffix → mutex field names,
/// outermost first). A lock name absent here is untracked.
const MANIFEST: &[(&str, &[&str])] = &[
    ("platform/batcher.rs", &["open", "inner"]),
    ("platform/async_invoke.rs", &["queue", "results", "workers"]),
    ("platform/pool.rs", &["idle", "waiters"]),
    ("platform/maintainer.rs", &["stop"]),
    ("platform/snapshots.rs", &["inner"]),
    ("platform/metrics.rs", &["totals", "recent"]),
    ("platform/dispatcher.rs", &["depth_by_fn"]),
    ("platform/invoker.rs", &["map", "maintainer"]),
    ("platform/billing.rs", &["lines"]),
    ("platform/scaler.rs", &["rng"]),
    ("runtime/mock.rs", &["compiled", "instances"]),
    ("runtime/pjrt.rs", &["joins"]),
];

/// One tracked lock currently (conservatively) held.
struct Guard {
    name: String,
    rank: usize,
    /// Brace depth at acquisition.
    depth: usize,
    /// `Some(var)` for `let var = …` guards, `None` for temporaries.
    binding: Option<String>,
    line: u32,
}

pub fn check(ctx: &FileCtx) -> Vec<Finding> {
    let Some(order) = MANIFEST
        .iter()
        .find(|(suffix, _)| ctx.path.ends_with(suffix))
        .map(|(_, names)| *names)
    else {
        return Vec::new();
    };
    let toks = &ctx.toks;
    let mut out = Vec::new();
    let mut held: Vec<Guard> = Vec::new();
    let mut depth = 0usize;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind == TokKind::Comment {
            continue;
        }
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => {
                    depth += 1;
                    continue;
                }
                "}" => {
                    depth = depth.saturating_sub(1);
                    // Block close ends every guard born inside it, and
                    // the statement (so the temporaries) of the block's
                    // own depth.
                    held.retain(|g| g.depth <= depth && !(g.binding.is_none() && g.depth == depth));
                    continue;
                }
                ";" => {
                    held.retain(|g| !(g.binding.is_none() && g.depth == depth));
                    continue;
                }
                _ => {}
            }
        }
        if ctx.is_test[i] {
            continue;
        }
        // `drop(name)` releases a let-bound guard early.
        if t.is(TokKind::Ident, "drop")
            && i + 3 < toks.len()
            && toks[i + 1].is(TokKind::Punct, "(")
            && toks[i + 2].kind == TokKind::Ident
            && toks[i + 3].is(TokKind::Punct, ")")
        {
            let name = toks[i + 2].text.as_str();
            held.retain(|g| g.binding.as_deref() != Some(name));
            continue;
        }
        // A condvar wait releases exactly the guard it consumes; any
        // second held lock stays held across the park — a waiter that
        // can deadlock every other toucher of that lock.
        let is_wait = (t.is(TokKind::Ident, "pwait_timeout")
            && i + 1 < toks.len()
            && toks[i + 1].is(TokKind::Punct, "(")
            && !(i > 0 && toks[i - 1].is(TokKind::Punct, ".")))
            || (t.is(TokKind::Punct, ".")
                && i + 2 < toks.len()
                && (toks[i + 1].is(TokKind::Ident, "wait")
                    || toks[i + 1].is(TokKind::Ident, "wait_timeout"))
                && toks[i + 2].is(TokKind::Punct, "("));
        if is_wait && held.len() >= 2 {
            let names: Vec<&str> = held.iter().map(|g| g.name.as_str()).collect();
            out.push(Finding {
                rule: LOCK_ORDER,
                file: ctx.path.clone(),
                line: t.line,
                message: format!(
                    "condvar wait while holding {} tracked locks ({}) — the wait releases \
                     only its own guard; drop the others first",
                    held.len(),
                    names.join(", ")
                ),
            });
        }
        // Acquisition A: `plock` `(` `&` <field path> `)`.
        if t.is(TokKind::Ident, "plock")
            && i + 2 < toks.len()
            && toks[i + 1].is(TokKind::Punct, "(")
            && toks[i + 2].is(TokKind::Punct, "&")
        {
            if let Some(name) = plain_path_after(toks, i + 3) {
                acquire(ctx, order, &mut held, &mut out, toks, i, depth, &name);
            }
            continue;
        }
        // Acquisition B: `<field path>` `.` `lock` `(` `)`.
        if t.is(TokKind::Punct, ".")
            && i + 3 < toks.len()
            && toks[i + 1].is(TokKind::Ident, "lock")
            && toks[i + 2].is(TokKind::Punct, "(")
            && toks[i + 3].is(TokKind::Punct, ")")
        {
            let segs = path_before(toks, i);
            if let Some(name) = segs.last().cloned() {
                let start = i - (2 * segs.len() - 1);
                acquire(ctx, order, &mut held, &mut out, toks, start, depth, &name);
            }
            continue;
        }
    }
    out
}

/// Forward-parse `ident (. ident)*` starting at `toks[i]`, requiring
/// the very next token to be `)`. Returns the final segment — the
/// lock's field name — or `None` for computed receivers (any `(`,
/// index, etc. in the path).
fn plain_path_after(toks: &[Tok], mut i: usize) -> Option<String> {
    let mut last: Option<String> = None;
    loop {
        if i >= toks.len() || toks[i].kind != TokKind::Ident {
            return None;
        }
        last = Some(toks[i].text.clone());
        i += 1;
        if i < toks.len() && toks[i].is(TokKind::Punct, ".") {
            i += 1;
            continue;
        }
        break;
    }
    if i < toks.len() && toks[i].is(TokKind::Punct, ")") {
        last
    } else {
        None
    }
}

#[allow(clippy::too_many_arguments)]
fn acquire(
    ctx: &FileCtx,
    order: &[&str],
    held: &mut Vec<Guard>,
    out: &mut Vec<Finding>,
    toks: &[Tok],
    start: usize,
    depth: usize,
    name: &str,
) {
    let Some(rank) = order.iter().position(|n| *n == name) else {
        return;
    };
    let line = toks[start].line;
    for g in held.iter() {
        if g.name == name {
            out.push(Finding {
                rule: LOCK_ORDER,
                file: ctx.path.clone(),
                line,
                message: format!(
                    "lock `{name}` acquired while already held (taken at line {}) — \
                     self-deadlock",
                    g.line
                ),
            });
        } else if rank < g.rank {
            out.push(Finding {
                rule: LOCK_ORDER,
                file: ctx.path.clone(),
                line,
                message: format!(
                    "acquires `{name}` while holding `{}` — the declared order for this \
                     file is [{}]",
                    g.name,
                    order.join(" < ")
                ),
            });
        }
    }
    // `let g = …` / `let mut g = …` binds the guard; anything else is
    // a temporary.
    let binding = if start >= 3
        && toks[start - 1].is(TokKind::Punct, "=")
        && toks[start - 2].kind == TokKind::Ident
        && (toks[start - 3].is(TokKind::Ident, "let")
            || (start >= 4
                && toks[start - 3].is(TokKind::Ident, "mut")
                && toks[start - 4].is(TokKind::Ident, "let")))
    {
        Some(toks[start - 2].text.clone())
    } else {
        None
    };
    // Rebinding a name implicitly drops the old guard.
    if let Some(b) = &binding {
        held.retain(|g| g.binding.as_deref() != Some(b.as_str()));
    }
    held.push(Guard { name: name.to_string(), rank, depth, binding, line });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(src: &str) -> Vec<Finding> {
        check(&FileCtx::new("rust/src/platform/batcher.rs", src))
    }

    #[test]
    fn manifest_order_nesting_is_legal() {
        let src = "fn f(&self) {\n    let open = plock(&self.open);\n    let g = plock(&state.inner);\n    drop(g);\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn reverse_nesting_is_flagged() {
        let src = "fn f(&self) {\n    let g = plock(&state.inner);\n    let open = plock(&self.open);\n}\n";
        let hits = lint(src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].rule, LOCK_ORDER);
        assert_eq!(hits[0].line, 3);
        assert!(hits[0].message.contains("declared order"), "{}", hits[0].message);
    }

    #[test]
    fn reacquiring_a_held_lock_is_flagged() {
        let src = "fn f(&self) {\n    let a = plock(&self.open);\n    let b = plock(&other.open);\n}\n";
        let hits = lint(src);
        assert_eq!(hits.len(), 1);
        assert!(hits[0].message.contains("self-deadlock"));
    }

    #[test]
    fn temporaries_die_at_their_statement() {
        // Sequential temps in reverse manifest order never overlap.
        let src = "fn f(&self) {\n    plock(&state.inner).seeds.len();\n    plock(&self.open).clear();\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn temporaries_live_across_an_attached_block() {
        // `if let` extends the guard across the arm (real Rust
        // temporary-scope semantics) — a nested reverse acquisition
        // inside the block is a genuine deadlock.
        let src = "fn f(&self) {\n    if let Some(s) = plock(&state.inner).shares.first() {\n        plock(&self.open).remove(k);\n    }\n}\n";
        let hits = lint(src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].line, 3);
    }

    #[test]
    fn drop_releases_a_let_bound_guard() {
        let src = "fn f(&self) {\n    let g = plock(&state.inner);\n    drop(g);\n    let open = plock(&self.open);\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn block_close_releases_let_bound_guards() {
        let src = "fn f(&self) {\n    {\n        let g = plock(&state.inner);\n    }\n    let open = plock(&self.open);\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn wait_while_holding_a_second_lock_is_flagged() {
        let src = "fn f(&self) {\n    let open = plock(&self.open);\n    let g = plock(&state.inner);\n    let (g, _) = pwait_timeout(&state.cv, g, d);\n}\n";
        let hits = lint(src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert!(hits[0].message.contains("condvar wait while holding"));
    }

    #[test]
    fn wait_with_only_its_own_guard_is_fine() {
        let src = "fn f(&self) {\n    let mut g = plock(&state.inner);\n    g = pwait_timeout(&state.cv, g, d).0;\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn computed_receivers_are_untracked() {
        let src = "fn f(&self) {\n    let open = plock(&self.open);\n    plock(&self.shard(name)).apply(r);\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn dot_lock_spelling_is_tracked_too() {
        let src = "fn f(&self) {\n    let g = state.inner.lock().unwrap();\n    let open = self.open.lock().unwrap();\n}\n";
        let hits = lint(src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert!(hits[0].message.contains("declared order"));
    }

    #[test]
    fn files_without_a_manifest_entry_are_skipped() {
        let src = "fn f() { let a = plock(&x.inner); let b = plock(&y.open); }\n";
        assert!(check(&FileCtx::new("platform/unlisted.rs", src)).is_empty());
    }
}
