//! Rule `stats-doc-drift`: the stats API and its documentation move
//! together.
//!
//! Every JSON field emitted by the two stats routes
//! (`rust/src/gateway/api/stats.rs`) must appear in the Stats section
//! of `API.md`, and every key documented there must actually be
//! emitted — in BOTH directions, so a new gauge cannot land
//! undocumented and a renamed one cannot leave its old name behind in
//! the reference. The comparison is union-set: a key may be shown in
//! either route's example block (the shard block is shared between
//! them, so documenting it once suffices).
//!
//! Emitted keys are read from the source tokens: string literals in
//! `("name", value)` pair position (previous token `(`, next `,`)
//! that look like JSON field names. Documented keys are read from the
//! ```json fenced blocks under the two `### GET …stats` headings.

use std::collections::BTreeSet;
use std::path::Path;

use crate::lints::tokenizer::{tokenize, TokKind};
use crate::lints::{Finding, STATS_DOC_DRIFT};

const STATS_SRC: &str = "rust/src/gateway/api/stats.rs";
const DOC: &str = "API.md";

/// Repo-level check: compare the emitted and documented stats keys.
/// `manifest_dir` is the crate root (`rust/`); API.md lives one level
/// up.
pub fn check_repo(manifest_dir: &Path) -> Vec<Finding> {
    let repo = manifest_dir.parent().unwrap_or(manifest_dir);
    let src_path = manifest_dir.join("src/gateway/api/stats.rs");
    let doc_path = repo.join(DOC);
    let mut out = Vec::new();
    let Ok(src) = std::fs::read_to_string(&src_path) else {
        out.push(whole_file(STATS_SRC, format!("cannot read {}", src_path.display())));
        return out;
    };
    let Ok(doc) = std::fs::read_to_string(&doc_path) else {
        out.push(whole_file(DOC, format!("cannot read {}", doc_path.display())));
        return out;
    };
    compare(&emitted_keys(&src), &documented_keys(&doc))
}

/// The comparison itself, separated for fixture tests.
pub fn compare(emitted: &BTreeSet<String>, documented: &BTreeSet<String>) -> Vec<Finding> {
    let mut out = Vec::new();
    for key in emitted.difference(documented) {
        out.push(whole_file(
            STATS_SRC,
            format!("stats field \"{key}\" is emitted but not documented in API.md's Stats section"),
        ));
    }
    for key in documented.difference(emitted) {
        out.push(whole_file(
            DOC,
            format!("stats field \"{key}\" is documented in API.md but never emitted by stats.rs"),
        ));
    }
    out
}

fn whole_file(file: &str, message: String) -> Finding {
    Finding { rule: STATS_DOC_DRIFT, file: file.to_string(), line: 0, message }
}

/// Field names emitted by stats.rs: string literals in `("name", …)`
/// pair position. The `(` Str `,` shape excludes every other string
/// in the file (route params, error messages, format strings).
pub fn emitted_keys(source: &str) -> BTreeSet<String> {
    let toks = tokenize(source);
    let mut keys = BTreeSet::new();
    for i in 1..toks.len().saturating_sub(1) {
        if toks[i].kind == TokKind::Str
            && toks[i - 1].is(TokKind::Punct, "(")
            && toks[i + 1].is(TokKind::Punct, ",")
            && is_field_name(&toks[i].text)
        {
            keys.insert(toks[i].text.clone());
        }
    }
    keys
}

/// Keys of every ```json block inside a `###` section whose heading
/// mentions "stats".
pub fn documented_keys(doc: &str) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    let mut in_stats_section = false;
    let mut in_json = false;
    for line in doc.lines() {
        if let Some(heading) = line.strip_prefix("###") {
            in_stats_section = heading.contains("stats");
            continue;
        }
        if line.starts_with("##") {
            in_stats_section = false;
            continue;
        }
        if !in_stats_section {
            continue;
        }
        if line.trim_start().starts_with("```") {
            in_json = !in_json && line.trim_start().starts_with("```json");
            continue;
        }
        if in_json {
            collect_json_keys(line, &mut keys);
        }
    }
    keys
}

/// Pull every `"key":` occurrence out of one line of a JSON example.
fn collect_json_keys(line: &str, keys: &mut BTreeSet<String>) {
    let mut rest = line;
    while let Some(start) = rest.find('"') {
        let after = &rest[start + 1..];
        let Some(end) = after.find('"') else { return };
        let (candidate, tail) = (&after[..end], &after[end + 1..]);
        if tail.trim_start().starts_with(':') && is_field_name(candidate) {
            keys.insert(candidate.to_string());
        }
        rest = tail;
    }
}

fn is_field_name(s: &str) -> bool {
    let mut chars = s.chars();
    matches!(chars.next(), Some('a'..='z'))
        && chars.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emitted_keys_sees_pair_literals_only() {
        let src = r#"
            fn fields() -> Vec<(&'static str, Json)> {
                vec![("invocations", Json::Num(1.0)), ("cold_starts", Json::Num(0.0))]
            }
            fn handler() -> Responder {
                let name = params.require("name");
                err(404, "not_found", &format!("function {name:?} is gone"))
            }
        "#;
        let keys = emitted_keys(src);
        assert!(keys.contains("invocations"));
        assert!(keys.contains("cold_starts"));
        assert!(!keys.contains("name"), "call-argument strings are not fields");
        assert!(!keys.contains("not_found"), "non-pair position is not a field");
    }

    #[test]
    fn documented_keys_reads_json_blocks_under_stats_headings_only() {
        let doc = "\
## Stats\n\n### `GET /v2/functions/:name/stats`\n\n```json\n{\"invocations\": 12,\n \"cold_starts\": 2}\n```\n\n### `GET /v2/stats`\n\n```json\n{\"functions\": 3}\n```\n\n## Other\n\n```json\n{\"unrelated\": 1}\n```\n";
        let keys = documented_keys(doc);
        assert_eq!(
            keys,
            ["invocations", "cold_starts", "functions"]
                .iter()
                .map(ToString::to_string)
                .collect()
        );
    }

    #[test]
    fn drift_is_reported_in_both_directions() {
        let emitted: BTreeSet<String> =
            ["invocations", "new_gauge"].iter().map(ToString::to_string).collect();
        let documented: BTreeSet<String> =
            ["invocations", "stale_key"].iter().map(ToString::to_string).collect();
        let out = compare(&emitted, &documented);
        assert_eq!(out.len(), 2);
        assert!(out.iter().any(|f| f.file == STATS_SRC && f.message.contains("new_gauge")));
        assert!(out.iter().any(|f| f.file == DOC && f.message.contains("stale_key")));
    }

    #[test]
    fn in_sync_sets_are_clean() {
        let keys: BTreeSet<String> = ["a_key"].iter().map(ToString::to_string).collect();
        assert!(compare(&keys, &keys).is_empty());
    }
}
