//! Rule `blocking-under-lock`: no tracked guard may be live across an
//! operation that can pause unboundedly (or for engine-scale time).
//!
//! The paper's tail-latency argument dies the moment a hot-path lock
//! is held across a multi-second pause: a cold-start `create_instance`
//! under the pool lock serializes every warm invocation behind one
//! provision. The blocking vocabulary (from the effect summaries):
//! condvar waits, `Clock::sleep`, channel `recv`/`recv_timeout`,
//! zero-arg thread `join()`, and the blocking `Engine` methods.
//!
//! Two shapes, both from the [`Summaries`] event stream:
//!
//! - **direct** — a block event with a non-empty held snapshot. A
//!   condvar wait is exempt for the one guard it *consumes* (the wait
//!   releases it while parked); any second lock still held across the
//!   park is the finding.
//! - **transitive** — a call made while holding a tracked lock, where
//!   some candidate callee's closed summary blocks. The finding prints
//!   the witness chain, so a two-hop `pool → helper → clock.sleep`
//!   reads as exactly that.

use crate::lints::rules::lock_order::name_of;
use crate::lints::summaries::{EventKind, Summaries};
use crate::lints::symbols::Program;
use crate::lints::{Finding, BLOCKING_UNDER_LOCK};
use std::collections::BTreeSet;

pub fn check(p: &Program, s: &Summaries) -> Vec<Finding> {
    let mut out = Vec::new();
    for (idx, evs) in s.events.iter().enumerate() {
        let path = &p.files[p.fns[idx].file].ctx.path;
        for e in evs {
            match &e.kind {
                EventKind::Call { name, cands } if !e.held.is_empty() => {
                    let mut tblk: BTreeSet<&str> = BTreeSet::new();
                    for &c in cands {
                        tblk.extend(s.blocks[c].iter().map(String::as_str));
                    }
                    if tblk.is_empty() {
                        continue;
                    }
                    let held: Vec<&str> = e.held.iter().map(|h| name_of(h.lock)).collect();
                    let kinds: Vec<&str> = tblk.iter().copied().collect();
                    let witness = cands
                        .iter()
                        .find_map(|&c| {
                            s.blocks[c].iter().next().map(|b| s.block_chain(p, c, b))
                        })
                        .unwrap_or_default();
                    out.push(Finding {
                        rule: BLOCKING_UNDER_LOCK,
                        file: path.clone(),
                        line: e.line,
                        message: format!(
                            "calls `{name}` which may block ({}) while holding [{}] [{witness}]",
                            kinds.join(", "),
                            held.join(", ")
                        ),
                    });
                }
                EventKind::Block { kind, own_guard } if !e.held.is_empty() => {
                    // A condvar wait releases the guard it consumes —
                    // that one lock is allowed across the park.
                    let others: Vec<&str> = e
                        .held
                        .iter()
                        .filter(|h| {
                            !(kind == "condvar-wait"
                                && h.binding.is_some()
                                && h.binding == *own_guard)
                        })
                        .map(|h| name_of(h.lock))
                        .collect();
                    if others.is_empty() {
                        continue;
                    }
                    out.push(Finding {
                        rule: BLOCKING_UNDER_LOCK,
                        file: path.clone(),
                        line: e.line,
                        message: format!(
                            "direct {kind} while holding [{}] — every other toucher of \
                             {} waits out the pause",
                            others.join(", "),
                            others[0]
                        ),
                    });
                }
                _ => {}
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lints::check_program;

    fn run(files: &[(&str, &str)]) -> Vec<Finding> {
        let owned: Vec<(String, String)> =
            files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect();
        check_program(&owned)
    }

    fn has(f: &[Finding], substr: &str) -> bool {
        f.iter().any(|x| x.rule == BLOCKING_UNDER_LOCK && x.message.contains(substr))
    }

    #[test]
    fn two_hop_blocking_chain_is_flagged() {
        // dispatcher lock held -> a() -> b() -> clock.sleep. The lock
        // holder and the sleeper are two hops apart.
        let f = run(&[(
            "rust/src/platform/dispatcher.rs",
            "pub struct Dispatcher { depth_by_fn: Mutex<u32>, h: Helper }\nimpl Dispatcher {\n    fn f(&self) {\n        let g = plock(&self.depth_by_fn);\n        self.h.a();\n    }\n}\npub struct Helper { clock: Arc<dyn Clock> }\nimpl Helper {\n    pub fn a(&self) { self.b(); }\n    pub fn b(&self) { self.clock.sleep(d); }\n}\n",
        )]);
        assert!(has(&f, "clock-sleep"), "{f:?}");
        assert!(has(&f, "Helper::b"), "witness chain reaches the sleeper: {f:?}");
    }

    #[test]
    fn wait_holding_a_second_lock_is_flagged() {
        let f = run(&[(
            "rust/src/platform/batcher.rs",
            "pub struct Batcher { open: Mutex<u32>, inner: Mutex<u32> }\nimpl Batcher {\n    fn f(&self) {\n        let o = plock(&self.open);\n        let mut g = plock(&self.inner);\n        let (g2, _) = pwait_timeout(&self.cv, g, d);\n    }\n}\n",
        )]);
        assert!(has(&f, "condvar-wait"), "{f:?}");
        assert!(has(&f, "batcher.open"), "the *other* lock is named: {f:?}");
    }

    #[test]
    fn wait_consuming_its_own_guard_is_exempt() {
        let f = run(&[(
            "rust/src/platform/batcher.rs",
            "pub struct Batcher { inner: Mutex<u32> }\nimpl Batcher {\n    fn f(&self) {\n        let mut g = plock(&self.inner);\n        let (g2, _) = pwait_timeout(&self.cv, g, d);\n    }\n}\n",
        )]);
        assert!(!f.iter().any(|x| x.rule == BLOCKING_UNDER_LOCK), "{f:?}");
    }

    #[test]
    fn engine_call_under_lock_is_flagged() {
        let f = run(&[(
            "rust/src/platform/pool.rs",
            "pub struct WarmPool { idle: Mutex<u32>, waiters: Mutex<u32>, engine: Arc<dyn Engine> }\nimpl WarmPool {\n    fn f(&self) {\n        let g = plock(&self.idle);\n        self.engine.predict(x);\n    }\n}\n",
        )]);
        assert!(has(&f, "engine-call:predict"), "{f:?}");
    }

    #[test]
    fn join_under_lock_is_flagged_and_drain_then_join_is_not() {
        // The shape the repo itself had in two Drop impls.
        let bad = run(&[(
            "rust/src/runtime/pjrt.rs",
            "pub struct PjrtEngine { joins: Mutex<Vec<JoinHandle<()>>> }\nimpl Drop for PjrtEngine {\n    fn drop(&mut self) {\n        for j in plock(&self.joins).drain(..) {\n            let _ = j.join();\n        }\n    }\n}\n",
        )]);
        assert!(has(&bad, "thread-join"), "{bad:?}");
        let good = run(&[(
            "rust/src/runtime/pjrt.rs",
            "pub struct PjrtEngine { joins: Mutex<Vec<JoinHandle<()>>> }\nimpl Drop for PjrtEngine {\n    fn drop(&mut self) {\n        let handles: Vec<JoinHandle<()>> = plock(&self.joins).drain(..).collect();\n        for j in handles {\n            let _ = j.join();\n        }\n    }\n}\n",
        )]);
        assert!(!good.iter().any(|x| x.rule == BLOCKING_UNDER_LOCK), "{good:?}");
    }

    #[test]
    fn lint_allow_suppresses_blocking_under_lock() {
        let f = run(&[(
            "rust/src/platform/pool.rs",
            "pub struct WarmPool { idle: Mutex<u32>, waiters: Mutex<u32>, engine: Arc<dyn Engine> }\nimpl WarmPool {\n    fn f(&self) {\n        let g = plock(&self.idle);\n        // lint:allow(blocking-under-lock: fixture proves suppression plumbing)\n        self.engine.predict(x);\n    }\n}\n",
        )]);
        assert!(!f.iter().any(|x| x.rule == BLOCKING_UNDER_LOCK), "{f:?}");
    }
}
