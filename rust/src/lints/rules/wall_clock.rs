//! Rule `wall-clock`: platform, gateway, and runtime non-test code
//! must not read or sleep on the wall clock directly.
//!
//! `util/clock.rs` is the platform's single source of time: every
//! timestamp, deadline, and sleep goes through the `Clock` trait so a
//! `ManualClock` test owns time completely. A stray `Instant::now()`
//! mixes wall time into a virtual run — the exact bug this PR fixed in
//! `maintainer.rs`, where the tick loop waited on wall deadlines while
//! eviction read virtual time. Sites that measure *real engine work*
//! (fed to `CpuGovernor::throttle`, which ignores them on virtual
//! clocks) carry a reasoned `lint:allow`.

use crate::lints::tokenizer::TokKind;
use crate::lints::{FileCtx, Finding, WALL_CLOCK};

use super::matches_seq;

pub fn check(ctx: &FileCtx) -> Vec<Finding> {
    let toks = &ctx.toks;
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if ctx.is_test[i] {
            continue;
        }
        let banned = if matches_seq(
            toks,
            i,
            &[
                (TokKind::Ident, "Instant"),
                (TokKind::Punct, ":"),
                (TokKind::Punct, ":"),
                (TokKind::Ident, "now"),
            ],
        ) {
            Some("Instant::now()")
        } else if matches_seq(
            toks,
            i,
            &[
                (TokKind::Ident, "SystemTime"),
                (TokKind::Punct, ":"),
                (TokKind::Punct, ":"),
                (TokKind::Ident, "now"),
            ],
        ) {
            Some("SystemTime::now()")
        } else if matches_seq(
            toks,
            i,
            &[
                (TokKind::Ident, "thread"),
                (TokKind::Punct, ":"),
                (TokKind::Punct, ":"),
                (TokKind::Ident, "sleep"),
            ],
        ) {
            Some("thread::sleep")
        } else {
            None
        };
        if let Some(what) = banned {
            out.push(Finding {
                rule: WALL_CLOCK,
                file: ctx.path.clone(),
                line: toks[i].line,
                message: format!(
                    "{what} in non-test platform code — route time through the Clock trait \
                     (clock.now() / clock.sleep()) so ManualClock runs stay virtual"
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(src: &str) -> Vec<Finding> {
        check(&FileCtx::new("platform/fixture.rs", src))
    }

    #[test]
    fn flags_all_three_wall_clock_forms() {
        let src = "fn f() {\n    let a = Instant::now();\n    let b = SystemTime::now();\n    std::thread::sleep(d);\n}\n";
        let hits = lint(src);
        assert_eq!(hits.len(), 3);
        assert_eq!(hits[0].line, 2);
        assert_eq!(hits[1].line, 3);
        assert_eq!(hits[2].line, 4);
        assert!(hits[2].message.contains("thread::sleep"));
    }

    #[test]
    fn ignores_test_code_comments_and_strings() {
        let src = "\
// Instant::now() in a comment\n\
/* thread::sleep in a block comment */\n\
fn f() { let s = \"Instant::now()\"; let r = r#\"SystemTime::now()\"#; }\n\
#[cfg(test)]\n\
mod tests {\n\
    fn t() { let a = Instant::now(); std::thread::sleep(d); }\n\
}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn import_alone_is_not_flagged() {
        // Importing the type is fine (tests may use it); calling
        // `::now` is what leaks wall time.
        assert!(lint("use std::time::{Duration, Instant};\n").is_empty());
    }

    #[test]
    fn clock_trait_calls_are_fine() {
        assert!(lint("fn f(c: &dyn Clock) { let t = c.now(); c.sleep(d); }\n").is_empty());
    }
}
