//! Rule `config-doc-drift`: the TOML config surface and its
//! documentation move together.
//!
//! Every `platform.*` / `snapshot.*` / `policy.*` / `trace.*` key parsed by
//! `rust/src/configparse/platform_config.rs` must appear in API.md's
//! `## Configuration` section, and every key documented there must
//! actually be parsed — BOTH directions, mirroring `stats-doc-drift`:
//! a new knob cannot land undocumented, and a renamed one cannot leave
//! its old spelling behind for operators to copy into dead config.
//!
//! Parsed keys are read from the source tokens: any non-test string
//! literal that is *exactly* a dotted key (`"platform.seed"`). Prose
//! strings that merely mention a key (`bail!("snapshot.restore_bw
//! must be positive")`) don't full-match and are ignored. Documented
//! keys are the first backticked cell of each table row in the
//! Configuration section.

use std::collections::BTreeSet;
use std::path::Path;

use crate::lints::tokenizer::TokKind;
use crate::lints::{FileCtx, Finding, CONFIG_DOC_DRIFT};

const CONFIG_SRC: &str = "rust/src/configparse/platform_config.rs";
const DOC: &str = "API.md";

/// Repo-level check: compare the parsed and documented config keys.
/// `manifest_dir` is the crate root (`rust/`); API.md lives one level
/// up.
pub fn check_repo(manifest_dir: &Path) -> Vec<Finding> {
    let repo = manifest_dir.parent().unwrap_or(manifest_dir);
    let src_path = manifest_dir.join("src/configparse/platform_config.rs");
    let doc_path = repo.join(DOC);
    let mut out = Vec::new();
    let Ok(src) = std::fs::read_to_string(&src_path) else {
        out.push(whole_file(CONFIG_SRC, format!("cannot read {}", src_path.display())));
        return out;
    };
    let Ok(doc) = std::fs::read_to_string(&doc_path) else {
        out.push(whole_file(DOC, format!("cannot read {}", doc_path.display())));
        return out;
    };
    compare(&parsed_keys(&src), &documented_keys(&doc))
}

/// The comparison itself, separated for fixture tests.
pub fn compare(parsed: &BTreeSet<String>, documented: &BTreeSet<String>) -> Vec<Finding> {
    let mut out = Vec::new();
    for key in parsed.difference(documented) {
        out.push(whole_file(
            CONFIG_SRC,
            format!(
                "config key \"{key}\" is parsed but not documented in API.md's \
                 Configuration section"
            ),
        ));
    }
    for key in documented.difference(parsed) {
        out.push(whole_file(
            DOC,
            format!(
                "config key \"{key}\" is documented in API.md but never parsed by \
                 platform_config.rs"
            ),
        ));
    }
    out
}

fn whole_file(file: &str, message: String) -> Finding {
    Finding { rule: CONFIG_DOC_DRIFT, file: file.to_string(), line: 0, message }
}

/// Keys the config parser actually reads: non-test string literals
/// that are exactly `platform.<ident>`, `snapshot.<ident>`,
/// `policy.<ident>`, or `trace.<ident>`.
pub fn parsed_keys(source: &str) -> BTreeSet<String> {
    let ctx = FileCtx::new(CONFIG_SRC, source);
    let mut keys = BTreeSet::new();
    for (i, t) in ctx.toks.iter().enumerate() {
        if t.kind == TokKind::Str && !ctx.is_test[i] && is_config_key(&t.text) {
            keys.insert(t.text.clone());
        }
    }
    keys
}

/// Keys documented in API.md: first backticked cell of each table row
/// inside the `## Configuration` section.
pub fn documented_keys(doc: &str) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    let mut in_config_section = false;
    for line in doc.lines() {
        if let Some(heading) = line.strip_prefix("## ") {
            in_config_section = heading.trim().starts_with("Configuration");
            continue;
        }
        if !in_config_section {
            continue;
        }
        let Some(row) = line.trim_start().strip_prefix('|') else { continue };
        let Some(cell) = row.split('|').next() else { continue };
        let cell = cell.trim().trim_matches('`');
        if is_config_key(cell) {
            keys.insert(cell.to_string());
        }
    }
    keys
}

/// Exactly `platform.<key>`, `snapshot.<key>`, `policy.<key>`, or
/// `trace.<key>` with a lowercase snake_case key — full match, no
/// surrounding prose.
fn is_config_key(s: &str) -> bool {
    let Some((section, key)) = s.split_once('.') else { return false };
    if section != "platform"
        && section != "snapshot"
        && section != "policy"
        && section != "trace"
    {
        return false;
    }
    let mut chars = key.chars();
    matches!(chars.next(), Some('a'..='z'))
        && chars.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parsed_keys_full_match_only_and_skip_tests() {
        let src = r#"
            fn overlay() {
                if let Some(v) = get_u64("platform.seed") { cfg.seed = v; }
                if let Some(v) = get_f64("snapshot.restore_bw") { cfg.bw = v; }
                if let Some(v) = get_u64("policy.slo_target_ms") { cfg.slo = v; }
                if let Some(v) = get_f64("trace.sample_rate") { cfg.rate = v; }
                bail!("snapshot.restore_bw must be a positive number");
                bail!("trace.sample_rate must be in [0, 1] if you read prose");
            }
            #[cfg(test)]
            mod tests {
                fn t() { let _ = get_u64("platform.phantom_key"); }
            }
        "#;
        let keys = parsed_keys(src);
        assert!(keys.contains("platform.seed"));
        assert!(keys.contains("snapshot.restore_bw"));
        assert!(keys.contains("policy.slo_target_ms"));
        assert!(keys.contains("trace.sample_rate"));
        assert_eq!(keys.len(), 4, "prose and test strings excluded: {keys:?}");
    }

    #[test]
    fn documented_keys_read_configuration_tables_only() {
        let doc = "\
## Configuration\n\nProse mentioning `platform.not_a_row`.\n\n### `[platform]`\n\n| key | default |\n|-----|---------|\n| `platform.seed` | `0` |\n| `platform.max_containers` | `8` |\n\n### `[snapshot]`\n\n| key | default |\n|-----|---------|\n| `snapshot.enabled` | `false` |\n\n### `[trace]`\n\n| key | default |\n|-----|---------|\n| `trace.enabled` | `false` |\n\n## Batching\n\n| `platform.out_of_section` | `1` |\n";
        let keys = documented_keys(doc);
        assert_eq!(
            keys,
            ["platform.seed", "platform.max_containers", "snapshot.enabled", "trace.enabled"]
                .iter()
                .map(ToString::to_string)
                .collect()
        );
    }

    #[test]
    fn drift_is_reported_in_both_directions() {
        let parsed: BTreeSet<String> =
            ["platform.seed", "platform.new_knob"].iter().map(ToString::to_string).collect();
        let documented: BTreeSet<String> =
            ["platform.seed", "snapshot.stale_key"].iter().map(ToString::to_string).collect();
        let out = compare(&parsed, &documented);
        assert_eq!(out.len(), 2);
        assert!(out.iter().any(|f| f.file == CONFIG_SRC && f.message.contains("new_knob")));
        assert!(out.iter().any(|f| f.file == DOC && f.message.contains("stale_key")));
    }

    #[test]
    fn in_sync_sets_are_clean() {
        let keys: BTreeSet<String> =
            ["platform.seed"].iter().map(ToString::to_string).collect();
        assert!(compare(&keys, &keys).is_empty());
    }
}
