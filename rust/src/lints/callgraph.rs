//! Call-site resolution for the whole-program lint pass.
//!
//! Given a method call `recv.path.m(...)` inside a known function, find
//! the `fn` items it may dispatch to. Resolution is *typed* where the
//! receiver's type is recoverable — `self.field...` through struct
//! fields, a parameter name through its declared type — and falls back
//! to conservative name matching otherwise. Trait-typed receivers
//! (`Arc<dyn Engine>`) fan out to every impl of the trait plus the
//! trait's own default-method bodies, which is exactly the
//! may-analysis the lock rules need: if *any* implementation blocks,
//! the call site blocks.
//!
//! The name-match fallback is what keeps an unresolvable receiver from
//! silently dropping a call edge, and [`FALLBACK_DENY`] is what keeps
//! it honest: ubiquitous std-container/iterator/atomic method names
//! (`get`, `len`, `insert`, ...) are never matched by name — a
//! `guard.get(k)` on a `BTreeMap` must not resolve to some platform
//! type's unrelated `get`. Beyond the deny list, a name-match is taken
//! only when it is *unambiguous* (exactly one candidate method in
//! scope); two candidates would mean guessing, and a wrong edge
//! manufactures false deadlock findings.

use crate::lints::symbols::{FnDef, Program};

/// Method names never resolved by bare name matching: std
/// collection/iterator/atomic/primitive vocabulary. A receiver we
/// cannot type that calls one of these is treated as a leaf, not as a
/// platform call. Typed resolution is unaffected — a platform struct
/// that really defines `get` still resolves through its receiver type.
pub const FALLBACK_DENY: &[&str] = &[
    "get", "get_mut", "insert", "remove", "push", "push_back", "push_front", "pop", "pop_back",
    "pop_front", "len", "is_empty", "clear", "drain", "iter", "iter_mut", "into_iter", "contains",
    "contains_key", "entry", "clone", "take", "replace", "next", "last", "first", "retain",
    "extend", "append", "keys", "values", "unwrap", "unwrap_or", "expect", "map", "and_then",
    "or_insert", "or_default", "to_string", "as_ref", "as_str", "split", "trim", "parse", "send",
    "store", "load", "fetch_add", "fetch_sub", "swap", "min", "max", "abs", "floor", "ceil",
    "round", "cloned", "copied", "collect", "filter", "any", "all", "find", "fold", "sum",
    "count", "rev", "chain", "zip", "enumerate", "starts_with", "ends_with", "upgrade",
    "downgrade", "notify_all", "notify_one", "saturating_sub", "saturating_add", "checked_sub",
    "checked_add",
];

/// Resolve `.m(` on the receiver path `segs` (e.g. `["self", "pool"]`)
/// inside `caller`. Returns candidate indexes into `p.fns` — possibly
/// several for a trait receiver, empty when the call is a leaf.
pub fn resolve_method(p: &Program, caller: &FnDef, segs: &[String], m: &str) -> Vec<usize> {
    // Typed path: root the walk at `self`'s impl type or a parameter's
    // declared type, then follow struct fields.
    let mut ty: Option<String> = None;
    if let Some(first) = segs.first() {
        if first == "self" {
            ty = caller.self_type.clone();
        } else if let Some(info) = caller.params.get(first.as_str()) {
            ty = info.peeled.clone();
        }
        if ty.is_some() {
            for seg in &segs[1..] {
                ty = match ty {
                    Some(t) => p.field_type(&t, seg),
                    None => None,
                };
                if ty.is_none() {
                    break;
                }
            }
        }
    }
    let named = p.by_name.get(m).map(Vec::as_slice).unwrap_or(&[]);
    if let Some(t) = ty {
        let mut cands: Vec<usize> = named
            .iter()
            .copied()
            .filter(|&fi| {
                p.fns[fi].self_type.as_deref() == Some(t.as_str()) && !p.fns[fi].is_trait_decl
            })
            .collect();
        if cands.is_empty() {
            // A trait type: fan out to impls and trait default bodies.
            let impls = p.trait_impls.get(&t).map(Vec::as_slice).unwrap_or(&[]);
            cands = named
                .iter()
                .copied()
                .filter(|&fi| {
                    let f = &p.fns[fi];
                    f.self_type.as_ref().is_some_and(|st| impls.contains(st))
                        || (f.self_type.as_deref() == Some(t.as_str())
                            && f.is_trait_decl
                            && f.body.is_some())
                })
                .collect();
        }
        // A typed receiver resolves (or doesn't) on its own merits —
        // never through the name-match fallback.
        return cands;
    }
    if FALLBACK_DENY.contains(&m) {
        return Vec::new();
    }
    let cands: Vec<usize> = named
        .iter()
        .copied()
        .filter(|&fi| p.fns[fi].has_self && p.fns[fi].body.is_some())
        .collect();
    if cands.len() == 1 {
        cands
    } else {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(parts: &[&str]) -> Vec<String> {
        parts.iter().map(ToString::to_string).collect()
    }

    fn prog(src: &str) -> Program {
        Program::build(&[("rust/src/platform/fixture.rs".to_string(), src.to_string())])
    }

    #[test]
    fn self_field_resolves_through_struct_types() {
        let p = prog(
            "pub struct A { pool: Arc<WarmPool> }\nimpl A {\n    fn caller(&self) {}\n}\npub struct WarmPool;\nimpl WarmPool {\n    pub fn evict(&self) {}\n}\n",
        );
        let caller = p.fns.iter().find(|f| f.name == "caller").unwrap();
        let cands = resolve_method(&p, caller, &seg(&["self", "pool"]), "evict");
        assert_eq!(cands.len(), 1);
        assert_eq!(p.fns[cands[0]].name, "evict");
    }

    #[test]
    fn trait_receiver_fans_out_to_impls() {
        let p = prog(
            "pub struct A { engine: Arc<dyn Engine> }\nimpl A {\n    fn caller(&self) {}\n}\ntrait Engine {\n    fn warm(&self);\n}\npub struct Mock;\nimpl Engine for Mock {\n    fn warm(&self) {}\n}\npub struct Pjrt;\nimpl Engine for Pjrt {\n    fn warm(&self) {}\n}\n",
        );
        let caller = p.fns.iter().find(|f| f.name == "caller").unwrap();
        let cands = resolve_method(&p, caller, &seg(&["self", "engine"]), "warm");
        assert_eq!(cands.len(), 2, "both impls are candidates");
    }

    #[test]
    fn deny_listed_names_never_match_by_name() {
        let p = prog(
            "pub struct A;\nimpl A {\n    pub fn get(&self) {}\n    fn caller(&self) {}\n}\n",
        );
        let caller = p.fns.iter().find(|f| f.name == "caller").unwrap();
        // `unknown.get(...)` — untypeable receiver, denied name.
        assert!(resolve_method(&p, caller, &seg(&["unknown"]), "get").is_empty());
        // But the *typed* spelling still resolves.
        assert_eq!(resolve_method(&p, caller, &seg(&["self"]), "get").len(), 1);
    }

    #[test]
    fn ambiguous_fallback_resolves_nothing() {
        let p = prog(
            "pub struct A;\nimpl A {\n    pub fn reap(&self) {}\n    fn caller(&self) {}\n}\npub struct B;\nimpl B {\n    pub fn reap(&self) {}\n}\n",
        );
        let caller = p.fns.iter().find(|f| f.name == "caller").unwrap();
        assert!(resolve_method(&p, caller, &seg(&["unknown"]), "reap").is_empty());
    }

    #[test]
    fn unique_fallback_resolves() {
        let p = prog(
            "pub struct A;\nimpl A {\n    pub fn reap_idle(&self) {}\n    fn caller(&self) {}\n}\n",
        );
        let caller = p.fns.iter().find(|f| f.name == "caller").unwrap();
        let cands = resolve_method(&p, caller, &seg(&["unknown"]), "reap_idle");
        assert_eq!(cands.len(), 1);
    }
}
