//! A minimal comment- and string-aware Rust tokenizer.
//!
//! Just enough lexing for `pallas-lint`'s rules: identifiers, single
//! punctuation characters, and *opaque* literals. String/char literal
//! contents and comment bodies become single tokens, so `Instant::now`
//! inside a doc comment, a `"..."` fixture, or an `r#"..."#` raw
//! string can never trip a rule — while comments stay addressable for
//! `lint:allow` suppression parsing.

/// Token classes the rules dispatch on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `Instant`, `lock`, ...).
    Ident,
    /// One punctuation character (`.`, `(`, `{`, `#`, ...).
    Punct,
    /// String literal of any flavor (`"..."`, `r"..."`, `r#"..."#`,
    /// `b"..."`, `br#"..."#`); `text` is the raw content only.
    Str,
    /// Character or byte literal (`'a'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`) — distinguished from `Char` so `'a` never eats
    /// a quote.
    Lifetime,
    /// Line, block, or doc comment; `text` is the body without the
    /// delimiters (block comments keep interior newlines).
    Comment,
    /// Numeric literal (opaque).
    Num,
}

/// One token with its 1-indexed starting line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is(&self, kind: TokKind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. Unterminated literals/comments end at EOF rather
/// than erroring: the linter must degrade gracefully on any input.
pub fn tokenize(src: &str) -> Vec<Tok> {
    let chars: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut i = 0;
    let mut line: u32 = 1;
    let n = chars.len();
    // Count newlines inside a span and advance the cursor.
    macro_rules! bump {
        ($from:expr, $to:expr) => {
            for &ch in &chars[$from..$to.min(n)] {
                if ch == '\n' {
                    line += 1;
                }
            }
            i = $to;
        };
    }
    while i < n {
        let c = chars[i];
        let start_line = line;
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Comments.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let mut j = i + 2;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Comment,
                text: chars[i + 2..j].iter().collect(),
                line: start_line,
            });
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            // Nested block comments, per the Rust grammar.
            let mut depth = 1;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let body_end = if depth == 0 { j - 2 } else { j };
            toks.push(Tok {
                kind: TokKind::Comment,
                text: chars[i + 2..body_end.max(i + 2)].iter().collect(),
                line: start_line,
            });
            bump!(i, j);
            continue;
        }
        // Identifiers — including the raw/byte string prefixes `r`,
        // `b`, `br`, which hand off to the literal scanners below.
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_continue(chars[j]) {
                j += 1;
            }
            let word: String = chars[i..j].iter().collect();
            if (word == "r" || word == "br") && j < n && (chars[j] == '"' || chars[j] == '#') {
                if let Some((content, end)) = scan_raw_string(&chars, j) {
                    toks.push(Tok { kind: TokKind::Str, text: content, line: start_line });
                    bump!(i, end);
                    continue;
                }
            }
            if word == "b" && j < n && chars[j] == '"' {
                let (content, end) = scan_quoted(&chars, j);
                toks.push(Tok { kind: TokKind::Str, text: content, line: start_line });
                bump!(i, end);
                continue;
            }
            if word == "b" && j < n && chars[j] == '\'' {
                let end = scan_char_literal(&chars, j);
                toks.push(Tok { kind: TokKind::Char, text: String::new(), line: start_line });
                bump!(i, end);
                continue;
            }
            toks.push(Tok { kind: TokKind::Ident, text: word, line: start_line });
            i = j;
            continue;
        }
        // Plain strings.
        if c == '"' {
            let (content, end) = scan_quoted(&chars, i);
            toks.push(Tok { kind: TokKind::Str, text: content, line: start_line });
            bump!(i, end);
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if is_char_literal(&chars, i) {
                let end = scan_char_literal(&chars, i);
                toks.push(Tok { kind: TokKind::Char, text: String::new(), line: start_line });
                bump!(i, end);
                continue;
            }
            // Lifetime: consume the quote + identifier.
            let mut j = i + 1;
            while j < n && is_ident_continue(chars[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Lifetime,
                text: chars[i + 1..j].iter().collect(),
                line: start_line,
            });
            i = j;
            continue;
        }
        // Numbers (opaque; good enough to keep `0.5` from emitting a
        // `.` punct that could confuse method-chain patterns).
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n
                && (chars[j].is_ascii_alphanumeric()
                    || chars[j] == '_'
                    || (chars[j] == '.' && j + 1 < n && chars[j + 1].is_ascii_digit()))
            {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Num,
                text: chars[i..j].iter().collect(),
                line: start_line,
            });
            i = j;
            continue;
        }
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line: start_line });
        i += 1;
    }
    toks
}

/// `chars[at]` is `"`. Returns (content, index past the closing quote).
fn scan_quoted(chars: &[char], at: usize) -> (String, usize) {
    let n = chars.len();
    let mut j = at + 1;
    let mut content = String::new();
    while j < n {
        match chars[j] {
            '\\' if j + 1 < n => {
                content.push(chars[j]);
                content.push(chars[j + 1]);
                j += 2;
            }
            '"' => return (content, j + 1),
            c => {
                content.push(c);
                j += 1;
            }
        }
    }
    (content, n)
}

/// `chars[at]` is `"` or `#` right after an `r`/`br` prefix. Returns
/// (content, index past the closing delimiter), or `None` when this
/// isn't actually a raw string (e.g. `r#foo` raw identifiers).
fn scan_raw_string(chars: &[char], at: usize) -> Option<(String, usize)> {
    let n = chars.len();
    let mut hashes = 0;
    let mut j = at;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || chars[j] != '"' {
        return None;
    }
    j += 1;
    let content_start = j;
    while j < n {
        if chars[j] == '"' {
            let mut k = j + 1;
            let mut seen = 0;
            while k < n && seen < hashes && chars[k] == '#' {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return Some((chars[content_start..j].iter().collect(), k));
            }
        }
        j += 1;
    }
    Some((chars[content_start..].iter().collect(), n))
}

/// Disambiguate `'a'` (char) from `'a` (lifetime) at a `'`.
fn is_char_literal(chars: &[char], at: usize) -> bool {
    let n = chars.len();
    if at + 1 >= n {
        return false;
    }
    if chars[at + 1] == '\\' {
        return true;
    }
    // 'x' where x is any single char followed by a closing quote —
    // but NOT '' (empty) and not 'ident (lifetime).
    chars[at + 1] != '\'' && at + 2 < n && chars[at + 2] == '\''
}

/// `chars[at]` is the opening `'` of a confirmed char literal.
/// Returns the index past the closing quote.
fn scan_char_literal(chars: &[char], at: usize) -> usize {
    let n = chars.len();
    let mut j = at + 1;
    while j < n {
        match chars[j] {
            '\\' if j + 1 < n => j += 2,
            '\'' => return j + 1,
            _ => j += 1,
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        tokenize(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    fn idents(src: &str) -> Vec<String> {
        tokenize(src)
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn code_in_string_literals_is_opaque() {
        // The exact trap the wall-clock rule must not fall into.
        let src = r#"let s = "Instant::now()"; let t = 1;"#;
        assert!(!idents(src).contains(&"Instant".to_string()));
        assert!(kinds(src).contains(&(TokKind::Str, "Instant::now()".to_string())));
    }

    #[test]
    fn raw_strings_are_opaque_and_balanced() {
        let src = r##"let s = r#"x.lock().unwrap() "quoted" more"#; Instant"##;
        let ks = kinds(src);
        assert!(ks.contains(&(TokKind::Str, r#"x.lock().unwrap() "quoted" more"#.to_string())));
        // Tokenization resumes correctly after the raw terminator.
        assert!(idents(src).contains(&"Instant".to_string()));
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let src = r#"let a = b"Instant::now()"; let c = b'x';"#;
        assert!(!idents(src).contains(&"Instant".to_string()));
        assert!(kinds(src).iter().any(|(k, _)| *k == TokKind::Char));
    }

    #[test]
    fn comments_are_tokens_not_code() {
        let src = "// Instant::now() here\nlet x = 1; /* thread::sleep */";
        let toks = tokenize(src);
        assert!(!idents(src).contains(&"Instant".to_string()));
        assert!(!idents(src).contains(&"thread".to_string()));
        let comments: Vec<&str> =
            toks.iter().filter(|t| t.kind == TokKind::Comment).map(|t| t.text.as_str()).collect();
        assert_eq!(comments, vec![" Instant::now() here", " thread::sleep "]);
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ fn x() {}";
        assert_eq!(idents(src), vec!["fn", "x"]);
    }

    #[test]
    fn block_comment_containing_instant_now_spans_lines() {
        let src = "/* line one\n Instant::now()\n line three */\nfn after() {}";
        let toks = tokenize(src);
        assert_eq!(toks[0].kind, TokKind::Comment);
        assert_eq!(toks[0].line, 1);
        let f = toks.iter().find(|t| t.is(TokKind::Ident, "fn")).unwrap();
        assert_eq!(f.line, 4, "line counting survives multi-line comments");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { 'b' }";
        let toks = tokenize(src);
        let lifetimes: Vec<&str> =
            toks.iter().filter(|t| t.kind == TokKind::Lifetime).map(|t| t.text.as_str()).collect();
        assert_eq!(lifetimes, vec!["a", "a"]);
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Char).count(), 1);
    }

    #[test]
    fn escaped_quotes_and_chars() {
        let src = r#"let q = "say \"Instant\""; let c = '\''; let d = '\\'; fn after() {}"#;
        assert!(idents(src).contains(&"after".to_string()));
        assert!(!idents(src).contains(&"Instant".to_string()));
    }

    #[test]
    fn line_numbers_are_one_indexed_and_accurate() {
        let src = "fn a() {}\n\nfn b() {}\n";
        let toks = tokenize(src);
        let b = toks.iter().find(|t| t.is(TokKind::Ident, "b")).unwrap();
        assert_eq!(b.line, 3);
    }

    /// Multi-hash raw strings: `r##"..."##` only terminates at a quote
    /// followed by the *same* number of hashes, so `"#` inside is
    /// content, not a terminator.
    #[test]
    fn multi_hash_raw_string_ignores_shorter_terminators() {
        let src = r####"let s = r##"contains "# inside"##; fn after() {}"####;
        let toks = tokenize(src);
        let s = toks.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert_eq!(s.text, r##"contains "# inside"##);
        assert!(toks.iter().any(|t| t.is(TokKind::Ident, "after")), "resumed after terminator");
        assert!(!toks.iter().any(|t| t.is(TokKind::Ident, "inside")));
    }

    #[test]
    fn triple_hash_raw_string_swallows_double_hash_quote() {
        let src = "let s = r###\"deep \"## still\"###; fn after() {}";
        let toks = tokenize(src);
        let s = toks.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert_eq!(s.text, "deep \"## still");
        assert!(toks.iter().any(|t| t.is(TokKind::Ident, "after")));
    }

    #[test]
    fn raw_byte_strings_are_opaque() {
        let src = r##"let b = br#"bytes "quoted" x"#; fn after() {}"##;
        let toks = tokenize(src);
        let s = toks.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert_eq!(s.text, r#"bytes "quoted" x"#);
        assert!(toks.iter().any(|t| t.is(TokKind::Ident, "after")));
        assert!(!toks.iter().any(|t| t.is(TokKind::Ident, "quoted")));
    }

    /// A raw string spanning lines advances the line counter so tokens
    /// after it report accurate positions.
    #[test]
    fn multi_line_raw_string_advances_line_counter() {
        let src = "let s = r#\"one\ntwo\nthree\"#;\nfn after() {}\n";
        let toks = tokenize(src);
        let f = toks.iter().find(|t| t.is(TokKind::Ident, "fn")).unwrap();
        assert_eq!(f.line, 4);
    }

    /// `r#foo` is a raw *identifier*, not a truncated raw string — the
    /// scanner must not eat to end-of-file looking for a terminator.
    #[test]
    fn raw_identifier_is_not_a_raw_string() {
        let src = "let r#type = 1; fn after() {}";
        let toks = tokenize(src);
        assert!(toks.iter().all(|t| t.kind != TokKind::Str));
        assert!(toks.iter().any(|t| t.is(TokKind::Ident, "after")));
    }
}
