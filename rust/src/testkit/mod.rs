//! proptest-lite: property-based testing without the proptest crate.
//!
//! `forall` runs a property over N seeded random cases; on failure it
//! performs greedy input shrinking via the `Shrink` trait and reports
//! the minimal counterexample with the seed needed to replay it.
//! Coordinator invariants (routing, batching, pool state, billing
//! rounding) are property-tested with this.

use crate::util::SplitMix64;

/// Types that can generate themselves from a PRNG.
pub trait Arbitrary: Sized + Clone + std::fmt::Debug {
    fn arbitrary(rng: &mut SplitMix64) -> Self;

    /// Candidate smaller values (for shrinking). Default: none.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Arbitrary for u64 {
    fn arbitrary(rng: &mut SplitMix64) -> Self {
        // Mix small and large magnitudes.
        match rng.gen_range(0, 4) {
            0 => rng.gen_range(0, 16),
            1 => rng.gen_range(0, 1 << 10),
            2 => rng.gen_range(0, 1 << 32),
            _ => rng.next_u64(),
        }
    }

    fn shrink(&self) -> Vec<Self> {
        let x = *self;
        if x == 0 {
            return Vec::new();
        }
        // Binary-search-style candidates: 0, x/2, 3x/4, 7x/8, ..., x-1.
        // Greedy descent over these converges to the minimal failing
        // value in O(log^2 x) steps for monotone properties.
        let mut c = vec![0, x / 2];
        let mut d = x / 4;
        while d > 0 {
            c.push(x - d);
            d /= 2;
        }
        c.push(x - 1);
        c.dedup();
        c
    }
}

impl Arbitrary for u32 {
    fn arbitrary(rng: &mut SplitMix64) -> Self {
        u64::arbitrary(rng) as u32
    }

    fn shrink(&self) -> Vec<Self> {
        u64::shrink(&(*self as u64)).into_iter().map(|v| v as u32).collect()
    }
}

impl Arbitrary for f64 {
    fn arbitrary(rng: &mut SplitMix64) -> Self {
        match rng.gen_range(0, 4) {
            0 => 0.0,
            1 => rng.next_f64(),
            2 => rng.next_f64() * 1e6,
            _ => -rng.next_f64() * 1e3,
        }
    }

    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 {
            Vec::new()
        } else {
            vec![0.0, self / 2.0]
        }
    }
}

impl Arbitrary for bool {
    fn arbitrary(rng: &mut SplitMix64) -> Self {
        rng.gen_range(0, 2) == 1
    }

    fn shrink(&self) -> Vec<Self> {
        if *self {
            vec![false]
        } else {
            Vec::new()
        }
    }
}

impl<T: Arbitrary> Arbitrary for Vec<T> {
    fn arbitrary(rng: &mut SplitMix64) -> Self {
        let len = rng.gen_range(0, 20) as usize;
        (0..len).map(|_| T::arbitrary(rng)).collect()
    }

    fn shrink(&self) -> Vec<Self> {
        let mut c = Vec::new();
        if self.is_empty() {
            return c;
        }
        // Halve, drop one element, shrink one element.
        c.push(self[..self.len() / 2].to_vec());
        if self.len() > 1 {
            let mut v = self.clone();
            v.remove(0);
            c.push(v);
            let mut v = self.clone();
            v.pop();
            c.push(v);
        }
        for (i, x) in self.iter().enumerate() {
            for s in x.shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[i] = s;
                c.push(v);
            }
        }
        c
    }
}

impl<A: Arbitrary, B: Arbitrary> Arbitrary for (A, B) {
    fn arbitrary(rng: &mut SplitMix64) -> Self {
        (A::arbitrary(rng), B::arbitrary(rng))
    }

    fn shrink(&self) -> Vec<Self> {
        let mut c: Vec<Self> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        c.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        c
    }
}

/// Outcome of one property evaluation.
pub enum Prop {
    Pass,
    /// Skip this input (precondition unmet) — not counted as a case.
    Discard,
    Fail(String),
}

impl From<bool> for Prop {
    fn from(ok: bool) -> Self {
        if ok {
            Prop::Pass
        } else {
            Prop::Fail("property returned false".into())
        }
    }
}

impl From<Result<(), String>> for Prop {
    fn from(r: Result<(), String>) -> Self {
        match r {
            Ok(()) => Prop::Pass,
            Err(m) => Prop::Fail(m),
        }
    }
}

const DEFAULT_CASES: usize = 200;
const MAX_SHRINK_STEPS: usize = 500;

/// Run `prop` over `DEFAULT_CASES` random inputs; panic with the
/// shrunk counterexample on failure. Seed via `TESTKIT_SEED` env var to
/// replay a specific failure.
pub fn forall<T, F, P>(name: &str, prop: F)
where
    T: Arbitrary,
    F: Fn(&T) -> P,
    P: Into<Prop>,
{
    forall_cases(name, DEFAULT_CASES, prop)
}

pub fn forall_cases<T, F, P>(name: &str, cases: usize, prop: F)
where
    T: Arbitrary,
    F: Fn(&T) -> P,
    P: Into<Prop>,
{
    let seed = std::env::var("TESTKIT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x1ce_bee5);
    let mut rng = SplitMix64::new(seed);
    let mut ran = 0;
    let mut attempts = 0;
    while ran < cases {
        attempts += 1;
        if attempts > cases * 20 {
            panic!("property {name:?}: too many discards ({ran}/{cases} cases ran)");
        }
        let input = T::arbitrary(&mut rng);
        match prop(&input).into() {
            Prop::Pass => ran += 1,
            Prop::Discard => continue,
            Prop::Fail(msg) => {
                let (min_input, min_msg) = shrink_failure(&input, msg, &prop);
                panic!(
                    "property {name:?} failed (seed {seed}, case {ran}):\n  \
                     input: {min_input:?}\n  error: {min_msg}"
                );
            }
        }
    }
}

fn shrink_failure<T, F, P>(input: &T, msg: String, prop: &F) -> (T, String)
where
    T: Arbitrary,
    F: Fn(&T) -> P,
    P: Into<Prop>,
{
    let mut cur = input.clone();
    let mut cur_msg = msg;
    let mut steps = 0;
    'outer: while steps < MAX_SHRINK_STEPS {
        for cand in cur.shrink() {
            steps += 1;
            if let Prop::Fail(m) = prop(&cand).into() {
                cur = cand;
                cur_msg = m;
                continue 'outer;
            }
            if steps >= MAX_SHRINK_STEPS {
                break;
            }
        }
        break;
    }
    (cur, cur_msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        forall("u64 halves are smaller", |x: &u64| *x / 2 <= *x);
    }

    #[test]
    fn vec_reverse_involution() {
        forall("reverse twice is identity", |v: &Vec<u64>| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    fn tuple_property() {
        forall("addition commutes", |(a, b): &(u64, u64)| {
            a.wrapping_add(*b) == b.wrapping_add(*a)
        });
    }

    #[test]
    fn discard_preconditions() {
        forall("division well-defined for nonzero", |(a, b): &(u64, u64)| {
            if *b == 0 {
                return Prop::Discard;
            }
            Prop::from(a / b <= *a)
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_counterexample() {
        forall("all u64 are small (false)", |x: &u64| *x < 1000);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Catch the panic and verify the shrunk input is minimal (1000).
        let result = std::panic::catch_unwind(|| {
            forall("x < 1000", |x: &u64| *x < 1000);
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("input: 1000"), "shrunk to minimal: {msg}");
    }

    #[test]
    fn result_form() {
        forall("result-form properties work", |x: &u64| -> Result<(), String> {
            if *x == *x {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }
}
