//! Streaming statistics: log-bucketed histograms, means with 95%
//! confidence intervals (the paper reports "all results with 95%
//! confidence"), and percentile summaries for the SLA analysis.

mod histogram;
mod summary;
mod windowed;

pub use histogram::Histogram;
pub use summary::{mean_ci95, Summary, T_TABLE_975};
pub use windowed::WindowedHistogram;
