//! Mean ± 95% confidence interval (Student-t), matching the paper's
//! "all results are reported with 95% confidence".

/// Two-sided 97.5% Student-t critical values for df = 1..=30; beyond 30
/// the normal approximation (1.96) is used.
pub const T_TABLE_975: [f64; 30] = [
    12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
    2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
    2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
];

fn t975(df: usize) -> f64 {
    if df == 0 {
        f64::INFINITY
    } else if df <= 30 {
        T_TABLE_975[df - 1]
    } else {
        1.96
    }
}

/// `(mean, half_width)` of the 95% CI for the sample mean.
pub fn mean_ci95(xs: &[f64]) -> (f64, f64) {
    let n = xs.len();
    if n == 0 {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    if n == 1 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64;
    let se = (var / n as f64).sqrt();
    (mean, t975(n - 1) * se)
}

/// Aggregate sample summary used in experiment output rows.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub ci95: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn from_samples(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self::default();
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (mean, ci95) = mean_ci95(xs);
        let q = |p: f64| {
            let idx = ((p * sorted.len() as f64).ceil() as usize).max(1) - 1;
            sorted[idx.min(sorted.len() - 1)]
        };
        Self {
            n: xs.len(),
            mean,
            ci95,
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p50: q(0.50),
            p95: q(0.95),
            p99: q(0.99),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean_ci95(&[]), (0.0, 0.0));
        assert_eq!(mean_ci95(&[5.0]), (5.0, 0.0));
    }

    #[test]
    fn known_ci() {
        // n=5, mean=3, sd=sqrt(2.5), se=sqrt(0.5); t(4)=2.776.
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let (mean, hw) = mean_ci95(&xs);
        assert_eq!(mean, 3.0);
        let expect = 2.776 * (2.5f64 / 5.0).sqrt();
        assert!((hw - expect).abs() < 1e-9, "hw={hw} expect={expect}");
    }

    #[test]
    fn constant_samples_zero_width() {
        let xs = [7.0; 10];
        let (mean, hw) = mean_ci95(&xs);
        assert_eq!(mean, 7.0);
        assert_eq!(hw, 0.0);
    }

    #[test]
    fn large_n_uses_normal() {
        let xs: Vec<f64> = (0..1000).map(|i| (i % 10) as f64).collect();
        let (_, hw) = mean_ci95(&xs);
        // se = sd/sqrt(1000); sd of 0..9 uniform ≈ 2.8735 (sample).
        assert!(hw < 0.2);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::from_samples(&xs);
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.mean, 50.5);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::from_samples(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }
}
