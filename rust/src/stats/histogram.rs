//! HDR-style log-bucketed latency histogram.
//!
//! Buckets have ~1% relative width (128 sub-buckets per power of two),
//! so p50/p99 quantiles are accurate to ~1% across nanoseconds..hours
//! with a fixed 64 KiB footprint — good enough for the paper's
//! latency-distribution (bimodality) analysis and cheap enough for the
//! request hot path.

const SUB_BITS: u32 = 7; // 128 sub-buckets per octave
const SUB: u64 = 1 << SUB_BITS;

#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // 64 octaves x 128 sub-buckets.
        Self {
            counts: vec![0; (64 << SUB_BITS) as usize],
            total: 0,
            sum: 0.0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn index(v: u64) -> usize {
        if v < SUB {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros() as u64;
        let shift = msb - SUB_BITS as u64;
        let sub = (v >> shift) - SUB; // top SUB_BITS+1 bits minus leading 1
        (((msb - SUB_BITS as u64 + 1) << SUB_BITS) + sub as u64) as usize
    }

    /// Lower edge of the bucket holding `index` (representative value).
    fn value_of(index: usize) -> u64 {
        let index = index as u64;
        if index < SUB {
            return index;
        }
        let octave = (index >> SUB_BITS) - 1;
        let sub = index & (SUB - 1);
        (SUB + sub) << octave
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.total += 1;
        self.sum += v as f64;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn record_n(&mut self, v: u64, n: u64) {
        self.counts[Self::index(v)] += n;
        self.total += n;
        self.sum += (v as f64) * (n as f64);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Reset to empty in place, keeping the bucket allocation (the
    /// windowed ring recycles slices on rotation; reallocating the
    /// 64 KiB counts vector per slice expiry would churn the hot
    /// path).
    pub fn clear(&mut self) {
        self.counts.fill(0);
        self.total = 0;
        self.sum = 0.0;
        self.min = u64::MAX;
        self.max = 0;
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Quantile in `[0, 1]`; exact max for `q = 1`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        if q >= 1.0 {
            return self.max;
        }
        let target = ((q * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::value_of(i).max(self.min).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Fraction of samples strictly above `threshold` — the SLA
    /// violation rate for a latency target.
    pub fn fraction_above(&self, threshold: u64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let idx = Self::index(threshold);
        let above: u64 = self.counts[idx + 1..].iter().sum();
        above as f64 / self.total as f64
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Histogram(n={}, mean={:.1}, p50={}, p99={}, max={})",
            self.total,
            self.mean(),
            self.p50(),
            self.p99(),
            self.max
        )
    }
}

impl std::fmt::Display for Histogram {
    /// Human-facing summary line with the full percentile ladder —
    /// p95 included, since that is where batching/queueing trade-offs
    /// show before they reach the p99 tail.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.1} p50={} p95={} p99={} max={}",
            self.total,
            self.mean(),
            self.p50(),
            self.p95(),
            self.p99(),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.fraction_above(10), 0.0);
    }

    #[test]
    fn exact_below_128() {
        let mut h = Histogram::new();
        for v in 0..128 {
            h.record(v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 127);
        assert_eq!(h.quantile(0.5), 63);
    }

    #[test]
    fn relative_error_bounded() {
        let mut h = Histogram::new();
        let v = 1_234_567_890u64;
        h.record(v);
        let q = h.quantile(0.5);
        let rel = (q as f64 - v as f64).abs() / v as f64;
        assert!(rel < 0.01, "rel err {rel}");
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        h.record(10);
        h.record(20);
        h.record(30);
        assert_eq!(h.mean(), 20.0);
    }

    #[test]
    fn quantiles_monotone() {
        let mut h = Histogram::new();
        let mut r = crate::util::SplitMix64::new(5);
        for _ in 0..10_000 {
            h.record(r.gen_range(1, 1_000_000));
        }
        let mut last = 0;
        for q in [0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= last, "quantile not monotone at {q}");
            last = v;
        }
        assert_eq!(h.quantile(1.0), h.max());
    }

    #[test]
    fn uniform_quantiles_close() {
        let mut h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        let p50 = h.p50() as f64;
        assert!((p50 - 50_000.0).abs() / 50_000.0 < 0.02, "p50={p50}");
        let p99 = h.p99() as f64;
        assert!((p99 - 99_000.0).abs() / 99_000.0 < 0.02, "p99={p99}");
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        let mut r = crate::util::SplitMix64::new(9);
        for i in 0..1000 {
            let v = r.gen_range(1, 100_000);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.mean(), c.mean());
        assert_eq!(a.p99(), c.p99());
        assert_eq!(a.max(), c.max());
    }

    #[test]
    fn merge_with_empty_is_identity_both_ways() {
        let mut a = Histogram::new();
        a.record(10);
        a.record(1000);
        // Merging an empty histogram in must not disturb min/max/mean.
        a.merge(&Histogram::new());
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 10);
        assert_eq!(a.max(), 1000);
        assert_eq!(a.mean(), 505.0);
        // Merging into an empty histogram reproduces the source.
        let mut b = Histogram::new();
        b.merge(&a);
        assert_eq!(b.count(), 2);
        assert_eq!(b.min(), 10);
        assert_eq!(b.max(), 1000);
        assert_eq!(b.p99(), a.p99());
        // Empty + empty stays empty (and min() stays the reported 0).
        let mut e = Histogram::new();
        e.merge(&Histogram::new());
        assert_eq!(e.count(), 0);
        assert_eq!(e.min(), 0);
        assert_eq!(e.max(), 0);
    }

    #[test]
    fn display_includes_p95_between_p50_and_p99() {
        let mut h = Histogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        let s = h.to_string();
        assert!(s.contains("n=100"), "{s}");
        assert!(s.contains(&format!("p50={}", h.p50())), "{s}");
        assert!(s.contains(&format!("p95={}", h.p95())), "{s}");
        assert!(s.contains(&format!("p99={}", h.p99())), "{s}");
        assert!(s.contains("max=100"), "{s}");
        assert!(h.p50() <= h.p95() && h.p95() <= h.p99());
        // The p95 estimate is within the histogram's ~1% error band.
        assert!((h.p95() as i64 - 95).abs() <= 2, "p95={}", h.p95());
        // Empty histograms render all-zero, no panic.
        assert_eq!(Histogram::new().to_string(), "n=0 mean=0.0 p50=0 p95=0 p99=0 max=0");
    }

    #[test]
    fn fraction_above_bimodal() {
        // The paper's cold/warm bimodality: 95% at ~100ms, 5% at ~4s.
        let mut h = Histogram::new();
        h.record_n(100_000_000, 95); // 100ms in ns
        h.record_n(4_000_000_000, 5); // 4s
        let f = h.fraction_above(1_000_000_000); // 1s SLA
        assert!((f - 0.05).abs() < 1e-9, "f={f}");
    }

    #[test]
    fn record_n_matches_loop() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record_n(777, 10);
        for _ in 0..10 {
            b.record(777);
        }
        assert_eq!(a.count(), b.count());
        assert_eq!(a.mean(), b.mean());
        assert_eq!(a.p50(), b.p50());
    }
}
