//! Sliding-window percentiles: a ring of sub-histograms merged on
//! read.
//!
//! The all-time [`Histogram`] is the right tool for end-of-run
//! reports, but a feedback controller steering on it would chase
//! traffic from minutes ago: once a tail inflates the all-time p99,
//! no amount of recovery moves the estimate back down. The
//! [`WindowedHistogram`] keeps the last `window` of samples by
//! splitting it into `slices` time buckets; recording rotates the
//! ring (expired slices are cleared in place, no reallocation) and a
//! read merges the live slices into one ordinary [`Histogram`], so
//! every percentile/mean helper works unchanged on the recent view.
//!
//! Time is caller-supplied nanoseconds (the platform's virtual
//! [`crate::util::Clock`] domain) — the type itself never reads a
//! clock, which keeps it ManualClock-correct and trivially testable.

use super::Histogram;
use std::time::Duration;

struct Slice {
    /// Which ring rotation this slice's samples belong to
    /// (`now / slice_ns`); [`EMPTY_EPOCH`] until first use. A slot
    /// whose epoch is stale gets cleared before reuse, and a read
    /// skips slots older than the window.
    epoch: u64,
    hist: Histogram,
}

/// Sentinel for a never-used slice; unreachable as a real epoch (it
/// would need `now / slice_ns == u64::MAX`).
const EMPTY_EPOCH: u64 = u64::MAX;

pub struct WindowedHistogram {
    slices: Vec<Slice>,
    slice_ns: u64,
}

impl WindowedHistogram {
    /// A window of `window` split into `slices` ring slots (clamped to
    /// at least 1 each). Larger slice counts give smoother expiry at
    /// the cost of a 64 KiB histogram per slot.
    pub fn new(window: Duration, slices: usize) -> Self {
        let slices = slices.max(1);
        let slice_ns = ((window.as_nanos() as u64) / slices as u64).max(1);
        Self {
            slices: (0..slices).map(|_| Slice { epoch: EMPTY_EPOCH, hist: Histogram::new() }).collect(),
            slice_ns,
        }
    }

    fn slot(&self, epoch: u64) -> usize {
        (epoch % self.slices.len() as u64) as usize
    }

    /// Record `v` at (virtual) time `now_ns`. Reusing a slot whose
    /// epoch lies outside the current window clears it first — that is
    /// the entire expiry mechanism.
    pub fn record(&mut self, now_ns: u64, v: u64) {
        let epoch = now_ns / self.slice_ns;
        let slot = self.slot(epoch);
        let slice = &mut self.slices[slot];
        if slice.epoch != epoch {
            slice.hist.clear();
            slice.epoch = epoch;
        }
        slice.hist.record(v);
    }

    /// The recent view at `now_ns`: every slice younger than the
    /// window merged into one [`Histogram`]. Slices the ring has not
    /// rotated over yet are skipped by their epoch tag, so a read
    /// never needs to mutate (or lock out) the recorder's ring state.
    pub fn merged(&self, now_ns: u64) -> Histogram {
        let epoch = now_ns / self.slice_ns;
        let oldest = epoch.saturating_sub(self.slices.len() as u64 - 1);
        let mut out = Histogram::new();
        for slice in &self.slices {
            if slice.epoch != EMPTY_EPOCH && slice.epoch >= oldest && slice.epoch <= epoch {
                out.merge(&slice.hist);
            }
        }
        out
    }

    /// Samples currently inside the window (merged count).
    pub fn count(&self, now_ns: u64) -> u64 {
        self.merged(now_ns).count()
    }
}

impl std::fmt::Debug for WindowedHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WindowedHistogram(slices={}, slice_ns={})", self.slices.len(), self.slice_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = 1_000_000_000;

    fn wh() -> WindowedHistogram {
        // 8 slices of 1 s each.
        WindowedHistogram::new(Duration::from_secs(8), 8)
    }

    #[test]
    fn within_window_matches_plain_histogram() {
        let mut w = wh();
        let mut plain = Histogram::new();
        let mut r = crate::util::SplitMix64::new(3);
        for i in 0..1000u64 {
            let v = r.gen_range(1, 1_000_000);
            // Spread across 4 s — all inside the 8 s window.
            w.record(i * 4_000_000, v);
            plain.record(v);
        }
        let m = w.merged(4 * S);
        assert_eq!(m.count(), plain.count());
        assert_eq!(m.mean(), plain.mean());
        assert_eq!(m.p50(), plain.p50());
        assert_eq!(m.p99(), plain.p99());
        assert_eq!(m.max(), plain.max());
    }

    #[test]
    fn old_samples_age_out() {
        let mut w = wh();
        // A latency spike at t=0..1s.
        for _ in 0..100 {
            w.record(0, 5_000_000_000);
        }
        assert!(w.merged(S).p99() >= 4_900_000_000, "spike visible inside the window");
        // Healthy traffic 20 s later: the ring has rotated past the
        // spike's slice, so the recent p99 recovers.
        for i in 0..100u64 {
            w.record(20 * S + i, 1_000_000);
        }
        let recent = w.merged(20 * S);
        assert_eq!(recent.count(), 100, "spike samples expired");
        assert!(recent.p99() < 2_000_000, "recent p99 recovered, got {}", recent.p99());
    }

    #[test]
    fn slot_reuse_clears_stale_counts() {
        let mut w = WindowedHistogram::new(Duration::from_secs(2), 2);
        w.record(0, 100);
        w.record(S, 200);
        // t=2s maps onto slot 0 again: the t=0 sample must be gone.
        w.record(2 * S, 300);
        let m = w.merged(2 * S);
        assert_eq!(m.count(), 2);
        assert_eq!(m.min(), 200);
        assert_eq!(m.max(), 300);
    }

    #[test]
    fn read_far_in_the_future_is_empty() {
        let mut w = wh();
        for _ in 0..50 {
            w.record(0, 777);
        }
        assert_eq!(w.count(0), 50);
        assert_eq!(w.count(100 * S), 0, "everything expired");
        assert_eq!(w.merged(100 * S).p99(), 0);
    }

    #[test]
    fn empty_reads_and_degenerate_construction() {
        let w = WindowedHistogram::new(Duration::from_secs(1), 0);
        assert_eq!(w.count(0), 0, "slices clamp to 1, reads stay zero");
        let mut z = WindowedHistogram::new(Duration::ZERO, 4);
        z.record(123, 9); // slice_ns clamps to 1; must not divide by zero
        assert!(z.count(123) <= 1);
    }

    #[test]
    fn merged_is_stable_across_reads() {
        let mut w = wh();
        for i in 0..100u64 {
            w.record(i * 10_000_000, i + 1);
        }
        let a = w.merged(S);
        let b = w.merged(S);
        assert_eq!(a.count(), b.count(), "reads do not mutate ring state");
        assert_eq!(a.p99(), b.p99());
    }
}
