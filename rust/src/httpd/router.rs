//! Method + path-pattern routing with `:param` captures.
//!
//! Replaces the gateway's ad-hoc `match` over path segments. Routes
//! are registered as `(METHOD, "/v2/functions/:name/invocations")`;
//! dispatch walks the table, captures `:param` segments, and
//! distinguishes *unknown path* (404) from *known path, wrong method*
//! (405). Error fallbacks use the structured envelope
//! `{"error": {"code", "message"}}` shared with the API layer.

use super::server::{HttpRequest, Responder};
use crate::util::json::{obj, Json};
use std::collections::BTreeMap;

/// Captured `:param` path segments for one matched route.
#[derive(Debug, Default)]
pub struct Params(BTreeMap<String, String>);

impl Params {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.0.get(name).map(String::as_str)
    }

    /// Capture lookup that treats a missing capture as a bug: routes
    /// declare their params statically, so handlers may rely on them.
    pub fn require(&self, name: &str) -> &str {
        self.get(name).unwrap_or_default()
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Seg {
    Lit(String),
    Param(String),
}

type RouteHandler = Box<dyn Fn(&HttpRequest, &Params) -> Responder + Send + Sync>;

struct Route {
    method: String,
    pattern: Vec<Seg>,
    handler: RouteHandler,
}

impl Route {
    fn capture(&self, segs: &[&str]) -> Option<Params> {
        if segs.len() != self.pattern.len() {
            return None;
        }
        let mut params = BTreeMap::new();
        for (seg, pat) in segs.iter().zip(&self.pattern) {
            match pat {
                Seg::Lit(lit) => {
                    if lit.as_str() != *seg {
                        return None;
                    }
                }
                Seg::Param(name) => {
                    params.insert(name.clone(), (*seg).to_string());
                }
            }
        }
        Some(Params(params))
    }
}

fn parse_pattern(pattern: &str) -> Vec<Seg> {
    pattern
        .split('/')
        .filter(|s| !s.is_empty())
        .map(|s| match s.strip_prefix(':') {
            Some(name) => Seg::Param(name.to_string()),
            None => Seg::Lit(s.to_string()),
        })
        .collect()
}

/// JSON error envelope used by router fallbacks and API handlers.
pub fn error_envelope(code: &str, message: &str) -> String {
    obj(vec![(
        "error",
        obj(vec![
            ("code", Json::Str(code.to_string())),
            ("message", Json::Str(message.to_string())),
        ]),
    )])
    .to_string()
}

/// Ordered route table. First match wins.
#[derive(Default)]
pub struct Router {
    routes: Vec<Route>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `method pattern -> handler`; chainable.
    pub fn route<F>(mut self, method: &str, pattern: &str, handler: F) -> Self
    where
        F: Fn(&HttpRequest, &Params) -> Responder + Send + Sync + 'static,
    {
        self.routes.push(Route {
            method: method.to_ascii_uppercase(),
            pattern: parse_pattern(pattern),
            handler: Box::new(handler),
        });
        self
    }

    /// Dispatch a request: 404 for unknown paths, 405 when the path
    /// exists under a different method.
    pub fn dispatch(&self, req: &HttpRequest) -> Responder {
        let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
        let mut path_known = false;
        let mut allowed: Vec<&str> = Vec::new();
        for route in &self.routes {
            if let Some(params) = route.capture(&segs) {
                if route.method == req.method {
                    return (route.handler)(req, &params);
                }
                path_known = true;
                if !allowed.contains(&route.method.as_str()) {
                    allowed.push(route.method.as_str());
                }
            }
        }
        if path_known {
            Responder::json(
                405,
                error_envelope(
                    "method_not_allowed",
                    &format!(
                        "{} is not allowed for {} (allowed: {})",
                        req.method,
                        req.path,
                        allowed.join(", ")
                    ),
                ),
            )
        } else {
            Responder::json(
                404,
                error_envelope("not_found", &format!("no route for {}", req.path)),
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(method: &str, path: &str) -> HttpRequest {
        HttpRequest {
            method: method.to_string(),
            path: path.to_string(),
            query: BTreeMap::new(),
            headers: BTreeMap::new(),
            body: Vec::new(),
        }
    }

    fn router() -> Router {
        Router::new()
            .route("GET", "/v2/functions", |_, _| Responder::text(200, "list"))
            .route("POST", "/v2/functions", |_, _| Responder::text(201, "create"))
            .route("GET", "/v2/functions/:name", |_, p| {
                Responder::text(200, &format!("get {}", p.require("name")))
            })
            .route("POST", "/v2/functions/:name/invocations", |_, p| {
                Responder::text(200, &format!("invoke {}", p.require("name")))
            })
            .route("GET", "/healthz", |_, _| Responder::text(200, "ok"))
    }

    fn body(r: &Responder) -> String {
        String::from_utf8_lossy(&r.body).into_owned()
    }

    #[test]
    fn literal_and_param_dispatch() {
        let r = router();
        assert_eq!(body(&r.dispatch(&req("GET", "/v2/functions"))), "list");
        assert_eq!(body(&r.dispatch(&req("POST", "/v2/functions"))), "create");
        assert_eq!(body(&r.dispatch(&req("GET", "/v2/functions/sq"))), "get sq");
        assert_eq!(
            body(&r.dispatch(&req("POST", "/v2/functions/sq/invocations"))),
            "invoke sq"
        );
    }

    #[test]
    fn unknown_path_is_404() {
        let r = router();
        assert_eq!(r.dispatch(&req("GET", "/nope")).status, 404);
        assert_eq!(r.dispatch(&req("GET", "/v2/functions/sq/extra/deep")).status, 404);
        let resp = r.dispatch(&req("GET", "/missing"));
        let j = Json::parse(&body(&resp)).unwrap();
        assert_eq!(j.path(&["error", "code"]).unwrap().as_str(), Some("not_found"));
    }

    #[test]
    fn known_path_wrong_method_is_405() {
        let r = router();
        let resp = r.dispatch(&req("DELETE", "/v2/functions"));
        assert_eq!(resp.status, 405);
        let j = Json::parse(&body(&resp)).unwrap();
        assert_eq!(
            j.path(&["error", "code"]).unwrap().as_str(),
            Some("method_not_allowed")
        );
        let msg = j.path(&["error", "message"]).unwrap().as_str().unwrap().to_string();
        assert!(msg.contains("GET") && msg.contains("POST"), "{msg}");
        // Param routes too.
        assert_eq!(r.dispatch(&req("PUT", "/v2/functions/sq")).status, 405);
    }

    #[test]
    fn method_is_case_normalized_at_registration() {
        let r = Router::new().route("get", "/x", |_, _| Responder::text(200, "x"));
        assert_eq!(r.dispatch(&req("GET", "/x")).status, 200);
    }

    #[test]
    fn trailing_slash_is_equivalent() {
        let r = router();
        assert_eq!(body(&r.dispatch(&req("GET", "/v2/functions/"))), "list");
    }
}
