//! Threaded HTTP/1.1 server.

use crate::exec::ThreadPool;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Parsed query parameters.
    pub query: BTreeMap<String, String>,
    /// Lower-cased header names.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn query_param(&self, key: &str) -> Option<&str> {
        self.query.get(key).map(String::as_str)
    }
}

/// Response builder handed to the handler.
pub struct Responder {
    pub status: u16,
    pub content_type: String,
    /// Extra response headers (name, value); `Content-Type`,
    /// `Content-Length`, and `Connection` are emitted automatically.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Responder {
    pub fn json(status: u16, body: String) -> Self {
        Self {
            status,
            content_type: "application/json".into(),
            headers: Vec::new(),
            body: body.into_bytes(),
        }
    }

    pub fn text(status: u16, body: &str) -> Self {
        Self {
            status,
            content_type: "text/plain".into(),
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    /// Attach one extra response header (builder style), e.g. the
    /// `Retry-After` hint on 429/503 throttle responses.
    pub fn with_header(mut self, name: &str, value: &str) -> Self {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }
}

type Handler = dyn Fn(HttpRequest) -> Responder + Send + Sync + 'static;

pub struct HttpServer {
    listener: TcpListener,
    pool: ThreadPool,
    handler: Arc<Handler>,
    shutdown: Arc<AtomicBool>,
}

impl HttpServer {
    /// Bind to `addr` ("127.0.0.1:0" for an ephemeral port).
    pub fn bind<F>(addr: &str, threads: usize, handler: F) -> Result<Self>
    where
        F: Fn(HttpRequest) -> Responder + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Self {
            listener,
            pool: ThreadPool::new(threads, "httpd"),
            handler: Arc::new(handler),
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().unwrap()
    }

    /// Handle for stopping the accept loop from another thread.
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { flag: self.shutdown.clone(), addr: self.local_addr() }
    }

    /// Accept loop; returns when the shutdown handle fires.
    pub fn serve(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let handler = self.handler.clone();
            self.pool.execute(move || {
                let _ = handle_connection(stream, &handler);
            });
        }
        Ok(())
    }
}

pub struct ShutdownHandle {
    flag: Arc<AtomicBool>,
    addr: std::net::SocketAddr,
}

impl ShutdownHandle {
    pub fn shutdown(&self) {
        self.flag.store(true, Ordering::SeqCst);
        // Poke the accept loop awake.
        let _ = TcpStream::connect(self.addr);
    }
}

fn handle_connection(stream: TcpStream, handler: &Arc<Handler>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    loop {
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // clean close
            Err(e) => {
                let _ = write_response(&mut stream, &Responder::text(400, &e.to_string()), false);
                return Ok(());
            }
        };
        let keep_alive = req
            .headers
            .get("connection")
            .map(|v| !v.eq_ignore_ascii_case("close"))
            .unwrap_or(true);
        let resp = handler(req);
        write_response(&mut stream, &resp, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Option<HttpRequest>> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let target = parts.next().context("missing path")?.to_string();
    let version = parts.next().context("missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version}");
    }

    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            bail!("connection closed mid-headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':').context("malformed header")?;
        headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
    }

    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse())
        .transpose()
        .context("bad content-length")?
        .unwrap_or(0);
    if len > 64 << 20 {
        bail!("body too large");
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;

    let (path, query) = parse_target(&target);
    Ok(Some(HttpRequest { method, path, query, headers, body }))
}

fn parse_target(target: &str) -> (String, BTreeMap<String, String>) {
    match target.split_once('?') {
        None => (target.to_string(), BTreeMap::new()),
        Some((path, qs)) => {
            let mut q = BTreeMap::new();
            for pair in qs.split('&') {
                if pair.is_empty() {
                    continue;
                }
                let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
                q.insert(url_decode(k), url_decode(v));
            }
            (path.to_string(), q)
        }
    }
}

fn url_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            // A full "%XY" escape needs two bytes after the '%'.
            b'%' if i + 3 <= bytes.len() => {
                let hex = std::str::from_utf8(&bytes[i + 1..i + 3]).ok();
                if let Some(v) = hex.and_then(|h| u8::from_str_radix(h, 16).ok()) {
                    out.push(v);
                    i += 3;
                } else {
                    out.push(b'%');
                    i += 1;
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

fn write_response(stream: &mut TcpStream, resp: &Responder, keep_alive: bool) -> Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len(),
        conn
    );
    for (name, value) in &resp.headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_target_splits_query() {
        let (path, q) = parse_target("/invoke?model=squeezenet&mem=512");
        assert_eq!(path, "/invoke");
        assert_eq!(q["model"], "squeezenet");
        assert_eq!(q["mem"], "512");
    }

    #[test]
    fn parse_target_no_query() {
        let (path, q) = parse_target("/healthz");
        assert_eq!(path, "/healthz");
        assert!(q.is_empty());
    }

    #[test]
    fn url_decode_basics() {
        assert_eq!(url_decode("a%20b+c"), "a b c");
        assert_eq!(url_decode("100%"), "100%");
        assert_eq!(url_decode("x%2Fy"), "x/y");
    }

    #[test]
    fn url_decode_truncated_escape_at_end() {
        // A '%' with fewer than two hex bytes left must pass through
        // literally instead of reading out of bounds.
        assert_eq!(url_decode("%"), "%");
        assert_eq!(url_decode("%2"), "%2");
        assert_eq!(url_decode("abc%2"), "abc%2");
        assert_eq!(url_decode("%zz"), "%zz");
        assert_eq!(url_decode("%%41"), "%%41");
    }

    #[test]
    fn status_texts() {
        assert_eq!(status_text(200), "OK");
        assert_eq!(status_text(201), "Created");
        assert_eq!(status_text(202), "Accepted");
        assert_eq!(status_text(405), "Method Not Allowed");
        assert_eq!(status_text(409), "Conflict");
        assert_eq!(status_text(429), "Too Many Requests");
        assert_eq!(status_text(503), "Service Unavailable");
        assert_eq!(status_text(777), "Unknown");
    }

    /// Extra headers attached via `with_header` reach the wire (the
    /// gateway's `Retry-After` on 429/503 rides on this).
    #[test]
    fn extra_response_headers_are_emitted() {
        let server = HttpServer::bind("127.0.0.1:0", 2, |_req| {
            Responder::json(503, "{\"error\":\"busy\"}".to_string()).with_header("Retry-After", "2")
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let sh = server.shutdown_handle();
        let t = std::thread::spawn(move || server.serve().unwrap());
        let resp = crate::httpd::http_get(&addr, "/x", Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 503);
        assert_eq!(resp.headers.get("retry-after").map(String::as_str), Some("2"));
        assert_eq!(resp.body_str(), "{\"error\":\"busy\"}");
        sh.shutdown();
        t.join().unwrap();
    }

    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;
    use std::time::Duration;

    fn echo_server() -> (String, ShutdownHandle, std::thread::JoinHandle<()>) {
        let server = HttpServer::bind("127.0.0.1:0", 2, |req| {
            Responder::text(200, &format!("{} {} len={}", req.method, req.path, req.body.len()))
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let sh = server.shutdown_handle();
        let t = std::thread::spawn(move || {
            server.serve().unwrap();
        });
        (addr, sh, t)
    }

    /// Read one HTTP/1.1 response off `reader`; returns (status, body).
    fn read_one_response(reader: &mut BufReader<TcpStream>) -> (u16, String) {
        let mut status_line = String::new();
        reader.read_line(&mut status_line).unwrap();
        let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
        let mut len = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    len = v.trim().parse().unwrap();
                }
            }
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).unwrap();
        (status, String::from_utf8_lossy(&body).into_owned())
    }

    #[test]
    fn keep_alive_pipelines_requests_on_one_connection() {
        let (addr, sh, t) = echo_server();
        let stream = TcpStream::connect(&addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut w = stream.try_clone().unwrap();
        // Two requests written back-to-back before reading anything.
        w.write_all(
            b"GET /first HTTP/1.1\r\nHost: x\r\n\r\n\
              POST /second HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello",
        )
        .unwrap();
        w.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let (s1, b1) = read_one_response(&mut reader);
        assert_eq!((s1, b1.as_str()), (200, "GET /first len=0"));
        let (s2, b2) = read_one_response(&mut reader);
        assert_eq!((s2, b2.as_str()), (200, "POST /second len=5"));
        sh.shutdown();
        t.join().unwrap();
    }

    #[test]
    fn zero_length_body_post() {
        let (addr, sh, t) = echo_server();
        let stream = TcpStream::connect(&addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut w = stream.try_clone().unwrap();
        w.write_all(b"POST /empty HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n").unwrap();
        w.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let (status, body) = read_one_response(&mut reader);
        assert_eq!(status, 200);
        assert_eq!(body, "POST /empty len=0");
        sh.shutdown();
        t.join().unwrap();
    }

    #[test]
    fn oversized_content_length_rejected() {
        let (addr, sh, t) = echo_server();
        let stream = TcpStream::connect(&addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut w = stream.try_clone().unwrap();
        // Claims a body far over the 64 MB cap; server must refuse
        // before attempting to allocate or read it.
        w.write_all(b"POST /big HTTP/1.1\r\nHost: x\r\nContent-Length: 999999999999\r\n\r\n")
            .unwrap();
        w.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let (status, body) = read_one_response(&mut reader);
        assert_eq!(status, 400);
        assert!(body.contains("body too large"), "body={body}");
        sh.shutdown();
        t.join().unwrap();
    }

    #[test]
    fn malformed_content_length_rejected() {
        let (addr, sh, t) = echo_server();
        let stream = TcpStream::connect(&addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut w = stream.try_clone().unwrap();
        w.write_all(b"POST /bad HTTP/1.1\r\nHost: x\r\nContent-Length: lots\r\n\r\n").unwrap();
        w.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let (status, _) = read_one_response(&mut reader);
        assert_eq!(status, 400);
        sh.shutdown();
        t.join().unwrap();
    }
}
