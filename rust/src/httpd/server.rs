//! Threaded HTTP/1.1 server.

use crate::exec::ThreadPool;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Parsed query parameters.
    pub query: BTreeMap<String, String>,
    /// Lower-cased header names.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn query_param(&self, key: &str) -> Option<&str> {
        self.query.get(key).map(String::as_str)
    }
}

/// Response builder handed to the handler.
pub struct Responder {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl Responder {
    pub fn json(status: u16, body: String) -> Self {
        Self { status, content_type: "application/json".into(), body: body.into_bytes() }
    }

    pub fn text(status: u16, body: &str) -> Self {
        Self { status, content_type: "text/plain".into(), body: body.as_bytes().to_vec() }
    }
}

type Handler = dyn Fn(HttpRequest) -> Responder + Send + Sync + 'static;

pub struct HttpServer {
    listener: TcpListener,
    pool: ThreadPool,
    handler: Arc<Handler>,
    shutdown: Arc<AtomicBool>,
}

impl HttpServer {
    /// Bind to `addr` ("127.0.0.1:0" for an ephemeral port).
    pub fn bind<F>(addr: &str, threads: usize, handler: F) -> Result<Self>
    where
        F: Fn(HttpRequest) -> Responder + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Self {
            listener,
            pool: ThreadPool::new(threads, "httpd"),
            handler: Arc::new(handler),
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().unwrap()
    }

    /// Handle for stopping the accept loop from another thread.
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { flag: self.shutdown.clone(), addr: self.local_addr() }
    }

    /// Accept loop; returns when the shutdown handle fires.
    pub fn serve(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let handler = self.handler.clone();
            self.pool.execute(move || {
                let _ = handle_connection(stream, &handler);
            });
        }
        Ok(())
    }
}

pub struct ShutdownHandle {
    flag: Arc<AtomicBool>,
    addr: std::net::SocketAddr,
}

impl ShutdownHandle {
    pub fn shutdown(&self) {
        self.flag.store(true, Ordering::SeqCst);
        // Poke the accept loop awake.
        let _ = TcpStream::connect(self.addr);
    }
}

fn handle_connection(stream: TcpStream, handler: &Arc<Handler>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    loop {
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // clean close
            Err(e) => {
                let _ = write_response(&mut stream, 400, "text/plain", e.to_string().as_bytes(), false);
                return Ok(());
            }
        };
        let keep_alive = req
            .headers
            .get("connection")
            .map(|v| !v.eq_ignore_ascii_case("close"))
            .unwrap_or(true);
        let resp = handler(req);
        write_response(&mut stream, resp.status, &resp.content_type, &resp.body, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Option<HttpRequest>> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let target = parts.next().context("missing path")?.to_string();
    let version = parts.next().context("missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version}");
    }

    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            bail!("connection closed mid-headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':').context("malformed header")?;
        headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
    }

    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse())
        .transpose()
        .context("bad content-length")?
        .unwrap_or(0);
    if len > 64 << 20 {
        bail!("body too large");
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;

    let (path, query) = parse_target(&target);
    Ok(Some(HttpRequest { method, path, query, headers, body }))
}

fn parse_target(target: &str) -> (String, BTreeMap<String, String>) {
    match target.split_once('?') {
        None => (target.to_string(), BTreeMap::new()),
        Some((path, qs)) => {
            let mut q = BTreeMap::new();
            for pair in qs.split('&') {
                if pair.is_empty() {
                    continue;
                }
                let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
                q.insert(url_decode(k), url_decode(v));
            }
            (path.to_string(), q)
        }
    }
}

fn url_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() + 1 && i + 2 <= bytes.len() - 1 + 1 => {
                let hex = std::str::from_utf8(&bytes[i + 1..(i + 3).min(bytes.len())]).ok();
                if let Some(v) = hex.and_then(|h| u8::from_str_radix(h, 16).ok()) {
                    out.push(v);
                    i += 3;
                } else {
                    out.push(b'%');
                    i += 1;
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status,
        status_text(status),
        content_type,
        body.len(),
        conn
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_target_splits_query() {
        let (path, q) = parse_target("/invoke?model=squeezenet&mem=512");
        assert_eq!(path, "/invoke");
        assert_eq!(q["model"], "squeezenet");
        assert_eq!(q["mem"], "512");
    }

    #[test]
    fn parse_target_no_query() {
        let (path, q) = parse_target("/healthz");
        assert_eq!(path, "/healthz");
        assert!(q.is_empty());
    }

    #[test]
    fn url_decode_basics() {
        assert_eq!(url_decode("a%20b+c"), "a b c");
        assert_eq!(url_decode("100%"), "100%");
        assert_eq!(url_decode("x%2Fy"), "x/y");
    }

    #[test]
    fn status_texts() {
        assert_eq!(status_text(200), "OK");
        assert_eq!(status_text(429), "Too Many Requests");
        assert_eq!(status_text(777), "Unknown");
    }
}
