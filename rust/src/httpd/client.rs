//! Tiny blocking HTTP/1.1 client (for the load generator and tests).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

pub fn http_get(addr: &str, path_and_query: &str, timeout: Duration) -> Result<HttpResponse> {
    http_request(addr, "GET", path_and_query, &[], timeout)
}

pub fn http_post(
    addr: &str,
    path_and_query: &str,
    body: &[u8],
    timeout: Duration,
) -> Result<HttpResponse> {
    http_request(addr, "POST", path_and_query, body, timeout)
}

pub fn http_patch(
    addr: &str,
    path_and_query: &str,
    body: &[u8],
    timeout: Duration,
) -> Result<HttpResponse> {
    http_request(addr, "PATCH", path_and_query, body, timeout)
}

pub fn http_delete(addr: &str, path_and_query: &str, timeout: Duration) -> Result<HttpResponse> {
    http_request(addr, "DELETE", path_and_query, &[], timeout)
}

/// One blocking request with an arbitrary method (the typed client SDK
/// builds on this).
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    timeout: Duration,
) -> Result<HttpResponse> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.set_nodelay(true).ok();
    let mut w = stream.try_clone()?;
    write!(
        w,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    w.write_all(body)?;
    w.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let mut parts = status_line.split_whitespace();
    let version = parts.next().context("empty response")?;
    if !version.starts_with("HTTP/1.") {
        bail!("bad response version: {version}");
    }
    let status: u16 = parts.next().context("missing status")?.parse().context("bad status")?;

    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            bail!("eof in headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }

    let body = if let Some(len) = headers.get("content-length") {
        let len: usize = len.parse().context("bad content-length")?;
        let mut buf = vec![0u8; len];
        reader.read_exact(&mut buf)?;
        buf
    } else {
        let mut buf = Vec::new();
        reader.read_to_end(&mut buf)?;
        buf
    };
    Ok(HttpResponse { status, headers, body })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::httpd::{HttpServer, Responder};

    /// End-to-end loopback: server + client round-trip.
    #[test]
    fn get_roundtrip() {
        let server = HttpServer::bind("127.0.0.1:0", 2, |req| {
            assert_eq!(req.method, "GET");
            let model = req.query_param("model").unwrap_or("none").to_string();
            Responder::json(200, format!("{{\"model\":\"{model}\"}}"))
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let shutdown = server.shutdown_handle();
        let t = std::thread::spawn(move || server.serve());

        let resp =
            http_get(&addr, "/invoke?model=squeezenet", Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.headers["content-type"], "application/json");
        assert!(resp.body_str().contains("squeezenet"));

        shutdown.shutdown();
        t.join().unwrap().unwrap();
    }

    #[test]
    fn post_echoes_body_length() {
        let server = HttpServer::bind("127.0.0.1:0", 2, |req| {
            Responder::text(200, &format!("len={}", req.body.len()))
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let shutdown = server.shutdown_handle();
        let t = std::thread::spawn(move || server.serve());

        let resp = http_post(&addr, "/x", b"hello world", Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body_str(), "len=11");

        shutdown.shutdown();
        t.join().unwrap().unwrap();
    }

    #[test]
    fn many_concurrent_clients() {
        let server = HttpServer::bind("127.0.0.1:0", 8, |_req| Responder::text(200, "ok"))
            .unwrap();
        let addr = server.local_addr().to_string();
        let shutdown = server.shutdown_handle();
        let t = std::thread::spawn(move || server.serve());

        let handles: Vec<_> = (0..32)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    http_get(&addr, "/", Duration::from_secs(5)).unwrap().status
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }

        shutdown.shutdown();
        t.join().unwrap().unwrap();
    }
}
