//! Minimal HTTP/1.1 server + client over std::net (the API-Gateway
//! substrate — no hyper/axum in the offline dep closure).
//!
//! Supports what the gateway and examples need: request line + headers
//! parsing, Content-Length bodies, keep-alive, chunked responses are
//! NOT used (we always set Content-Length), and a tiny blocking client
//! for the load generator and tests.

mod client;
pub mod router;
pub mod server;

pub use client::{http_delete, http_get, http_patch, http_post, http_request, HttpResponse};
pub use router::{error_envelope, Params, Router};
pub use server::{HttpRequest, HttpServer, Responder, ShutdownHandle};
