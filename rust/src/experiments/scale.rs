//! Figure 7 (the step workload) and Figures 8-10 (scalability).
//!
//! Method (paper §3.4): generate requests at a rate that steps up by
//! 10 req/s every 10 s (Fig 7) and measure latency + prediction time
//! across memory sizes. Warm and cold starts mix — the paper "cannot
//! distinguish" them; we can, and report the cold fraction as an extra
//! column the paper couldn't produce.
//!
//! Scalability runs on the real clock with the calibrated mock engine
//! by default (`--engine pjrt` for the real artifacts at reduced
//! rates): the paper-scale ramp peaks at 100 req/s with multi-second
//! effective service times — thousands of concurrent containers, which
//! is exactly the regime Lambda's horizontal scaling absorbs and a
//! single host cannot compute in real time. `ctx.scale` shrinks the
//! ramp (default 0.2) while preserving its shape.

use super::report::{secs, write_csv, Table};
use super::{EngineKind, ExpCtx};
use crate::platform::Invoker;
use crate::stats::mean_ci95;
use crate::workload::{run_open_loop, Schedule, StepRamp};
use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

/// Memory sizes the paper highlights in Figures 8-10 (subset of the
/// full sweep keeps the real-time experiment bounded).
const SCALE_MEMS: [u32; 6] = [128, 256, 512, 768, 1024, 1536];

pub fn print_fig7(ctx: &ExpCtx) -> Result<()> {
    let ramp = StepRamp::paper();
    let mut t = Table::new(
        "fig7: step workload (paper configuration)",
        &["Step", "t (s)", "Rate (req/s)", "Requests in step"],
    );
    for k in 0..ramp.steps {
        let rate = ramp.rate_at_step(k);
        t.row(vec![
            (k + 1).to_string(),
            format!("{}-{}", k * 10, (k + 1) * 10),
            format!("{rate:.0}"),
            format!("{:.0}", rate * ramp.step.as_secs_f64()),
        ]);
    }
    t.row(vec!["total".into(), "0-100".into(), "-".into(), format!("{}", ramp.arrivals().len())]);
    t.print();
    write_csv(&t, &ctx.out_dir, "fig7")?;
    Ok(())
}

pub fn run_scale(ctx: &ExpCtx, model: &str, name: &str) -> Result<()> {
    let engine = ctx.build_engine()?;
    let factor = if ctx.scale > 0.0 { ctx.scale } else { 0.2 };
    let ramp = StepRamp::scaled(factor);
    let n_req = ramp.arrivals().len();
    let mut t = Table::new(
        &format!(
            "{name}: scalability ({model}); step ramp x{factor:.2} ({} reqs, peak {:.0} req/s)",
            n_req,
            ramp.rate_at_step(ramp.steps - 1)
        ),
        &[
            "Memory (MB)",
            "Latency (s)",
            "±CI",
            "Prediction (s)",
            "±CI",
            "Cold frac",
            "Rejected",
            "Peak conc",
            "Queue p95 (s)",
        ],
    );

    for mem in SCALE_MEMS {
        let platform = Arc::new(Invoker::live(ctx.config.clone(), engine.clone()));
        if platform.deploy("f", model, "pallas", mem).is_err() {
            t.row(vec![
                mem.to_string(),
                "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(),
                "-".into(),
            ]);
            continue;
        }
        // Client worker pool sized generously above peak concurrency.
        let workers = (n_req / 2).clamp(16, 512);
        let report = run_open_loop(&platform, "f", &ramp, ctx.config.seed ^ mem as u64, workers);
        let (lat, lat_ci) = mean_ci95(&report.latencies_s());
        let (prd, prd_ci) = mean_ci95(&report.predicts_s());
        let ok = report.ok_samples().len().max(1);
        t.row(vec![
            mem.to_string(),
            secs(lat),
            secs(lat_ci),
            secs(prd),
            secs(prd_ci),
            format!("{:.2}", report.cold_count() as f64 / ok as f64),
            // 429s (concurrency cap) + 503s (queue saturated): every
            // request the admission layer turned away.
            (report.throttled + report.saturated).to_string(),
            platform.scaler.high_water_mark().to_string(),
            // The dispatch-queue wait the admission layer traded for
            // those non-rejections — part of the latency column
            // already (records fold it into response time), surfaced
            // here so the trade is visible per memory size.
            secs(platform.metrics.with_totals(|m| m.queue_wait.p95()) as f64 / 1e9),
        ]);
        // Give the platform a beat to settle between memory sizes.
        if ctx.engine_kind == EngineKind::Pjrt {
            std::thread::sleep(Duration::from_millis(100));
        }
    }
    t.print();
    write_csv(&t, &ctx.out_dir, name)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_spec_matches_paper() {
        let mut ctx = ExpCtx::new(EngineKind::Mock);
        ctx.out_dir = std::env::temp_dir().join(format!("lambdaserve-f7-{}", std::process::id()));
        print_fig7(&ctx).unwrap();
        let csv = std::fs::read_to_string(ctx.out_dir.join("fig7.csv")).unwrap();
        assert!(csv.contains("1,0-10,10,100"));
        assert!(csv.contains("10,90-100,100,1000"));
        assert!(csv.contains("total,0-100,-,5500"));
        std::fs::remove_dir_all(ctx.out_dir).ok();
    }

    #[test]
    fn scale_run_tiny() {
        let mut ctx = ExpCtx::new(EngineKind::Mock);
        ctx.out_dir = std::env::temp_dir().join(format!("lambdaserve-f8-{}", std::process::id()));
        ctx.scale = 0.02; // 5 steps of 0.2..1 rps over 2 s each
        run_scale(&ctx, "squeezenet", "fig8test").unwrap();
        let csv = std::fs::read_to_string(ctx.out_dir.join("fig8test.csv")).unwrap();
        let lat: Vec<f64> = csv
            .lines()
            .skip(1)
            .filter_map(|l| l.split(',').nth(1))
            .filter_map(|v| v.parse().ok())
            .collect();
        assert_eq!(lat.len(), 6);
        assert!(lat[0] > lat[5], "latency shrinks with memory: {lat:?}");
        std::fs::remove_dir_all(ctx.out_dir).ok();
    }
}
