//! Experiment harnesses: one per paper table/figure plus ablations.
//!
//! Experiment ids (see DESIGN.md §4): `table1`, `fig1`..`fig10`,
//! `abl-keepalive`, `abl-provisioned`, `abl-memopt`, `abl-kernel`.
//! Each prints paper-style rows and writes a CSV into `results/`.

mod ablations;
mod cold;
mod report;
mod scale;
mod table1;
mod warm;

pub use ablations::{run_kernel_ablation, run_keepalive_ablation, run_memopt, run_provisioned};
pub use cold::run_cold;
pub use report::{pct, write_csv, Table};
pub use scale::{print_fig7, run_scale};
pub use table1::run_table1;
pub use warm::run_warm;

use crate::configparse::PlatformConfig;
use crate::runtime::{Engine, MockEngine, PjrtEngine};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Which engine an experiment runs against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Real AOT artifacts on the PJRT CPU client.
    Pjrt,
    /// Synthetic costs calibrated to the measured artifacts
    /// (fast sweeps; see DESIGN.md §Calibration).
    Mock,
}

/// Shared experiment context.
pub struct ExpCtx {
    pub config: PlatformConfig,
    pub engine_kind: EngineKind,
    pub engine_shards: usize,
    /// Output directory for CSVs.
    pub out_dir: std::path::PathBuf,
    /// Scale factor for time-expensive sweeps (1.0 = paper scale).
    pub scale: f64,
    /// Repetitions for warm probes (paper: 25).
    pub reps: usize,
}

impl ExpCtx {
    pub fn new(engine_kind: EngineKind) -> Self {
        Self {
            config: PlatformConfig::default(),
            engine_kind,
            engine_shards: 2,
            out_dir: std::path::PathBuf::from("results"),
            scale: 1.0,
            reps: 25,
        }
    }

    pub fn build_engine(&self) -> Result<Arc<dyn Engine>> {
        // Seed the batch-kernel ladder before type erasure — the knob
        // lives on the concrete engines, not the `Engine` trait.
        match self.engine_kind {
            EngineKind::Mock => {
                let engine = MockEngine::paper_zoo();
                engine.set_batch_kernel_max(self.config.batch_kernel_max);
                Ok(Arc::new(engine))
            }
            EngineKind::Pjrt => {
                let dir = std::path::Path::new(&self.config.artifacts_dir);
                let engine = PjrtEngine::new(dir, self.engine_shards)?;
                engine.set_batch_kernel_max(self.config.batch_kernel_max);
                Ok(Arc::new(engine))
            }
        }
    }
}

/// The three paper models, in figure order.
pub const PAPER_MODELS: [&str; 3] = ["squeezenet", "resnet18", "resnext50"];

/// Dispatch by experiment id.
pub fn run(id: &str, ctx: &ExpCtx) -> Result<()> {
    match id {
        "table1" => run_table1(ctx),
        "fig1" => run_warm(ctx, "squeezenet", "fig1"),
        "fig2" => run_warm(ctx, "resnet18", "fig2"),
        "fig3" => run_warm(ctx, "resnext50", "fig3"),
        "fig4" => run_cold(ctx, "squeezenet", "fig4"),
        "fig5" => run_cold(ctx, "resnet18", "fig5"),
        "fig6" => run_cold(ctx, "resnext50", "fig6"),
        "fig7" => print_fig7(ctx),
        "fig8" => run_scale(ctx, "squeezenet", "fig8"),
        "fig9" => run_scale(ctx, "resnet18", "fig9"),
        "fig10" => run_scale(ctx, "resnext50", "fig10"),
        "abl-keepalive" => run_keepalive_ablation(ctx),
        "abl-provisioned" => run_provisioned(ctx),
        "abl-memopt" => run_memopt(ctx),
        "abl-kernel" => run_kernel_ablation(ctx),
        "all" => {
            for id in ALL_IDS {
                println!("\n=== experiment {id} ===");
                run(id, ctx)?;
            }
            Ok(())
        }
        _ => bail!("unknown experiment id {id:?}; valid: {ALL_IDS:?} or 'all'"),
    }
}

pub const ALL_IDS: [&str; 15] = [
    "table1", "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10",
    "abl-keepalive", "abl-provisioned", "abl-memopt", "abl-kernel",
];
