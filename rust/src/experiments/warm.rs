//! Figures 1-3: warm function execution across memory sizes.
//!
//! Method (paper §3.1-3.2): per memory size, deploy the model's
//! function, send one discarded request (absorbs the cold start), then
//! 25 sequential requests at 1 s intervals; report mean latency
//! (client-observed), mean prediction time, and total cost x1000, all
//! with 95% CIs.

use super::report::{cost_x1000, secs, write_csv, Table};
use super::ExpCtx;
use crate::configparse::MEMORY_SIZES_2017;
use crate::platform::Invoker;
use crate::stats::mean_ci95;
use crate::util::ManualClock;
use crate::workload::{run_closed_loop, WarmProbe};
use anyhow::Result;
use std::time::Duration;

pub fn run_warm(ctx: &ExpCtx, model: &str, name: &str) -> Result<()> {
    let engine = ctx.build_engine()?;
    let mut t = Table::new(
        &format!("{name}: warm execution ({model}); mean over {} requests [95% CI]", ctx.reps),
        &["Memory (MB)", "Latency (s)", "±CI", "Prediction (s)", "±CI", "Cost x1000 ($)"],
    );

    for mem in MEMORY_SIZES_2017 {
        let clock = ManualClock::new();
        let platform = Invoker::new(ctx.config.clone(), engine.clone(), clock);
        if platform.deploy("f", model, "pallas", mem).is_err() {
            // Below the model's peak-memory floor: the paper has no
            // data point here either (e.g. ResNeXt below 512 MB).
            t.row(vec![mem.to_string(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into()]);
            continue;
        }
        let probe = WarmProbe { requests: ctx.reps, interval: Duration::from_secs(1) };
        let report = run_closed_loop(&platform, "f", &probe, ctx.config.seed ^ mem as u64);
        let (lat, lat_ci) = mean_ci95(&report.latencies_s());
        let (prd, prd_ci) = mean_ci95(&report.predicts_s());
        t.row(vec![
            mem.to_string(),
            secs(lat),
            secs(lat_ci),
            secs(prd),
            secs(prd_ci),
            cost_x1000(report.total_cost()),
        ]);
    }
    t.print();
    write_csv(&t, &ctx.out_dir, name)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::EngineKind;

    fn ctx() -> ExpCtx {
        let mut ctx = ExpCtx::new(EngineKind::Mock);
        ctx.out_dir = std::env::temp_dir().join(format!("lambdaserve-warm-{}", std::process::id()));
        ctx.reps = 10;
        ctx
    }

    fn parse_col(csv: &str, col: usize) -> Vec<f64> {
        csv.lines()
            .skip(1)
            .filter_map(|l| l.split(',').nth(col))
            .filter_map(|v| v.parse().ok())
            .collect()
    }

    #[test]
    fn squeezenet_latency_decreases_with_memory() {
        let c = ctx();
        run_warm(&c, "squeezenet", "figtest").unwrap();
        let csv = std::fs::read_to_string(c.out_dir.join("figtest.csv")).unwrap();
        let lat = parse_col(&csv, 1);
        assert_eq!(lat.len(), 12, "squeezenet deployable at all sizes");
        // Monotone non-increasing up to jitter: compare endpoints.
        assert!(lat[0] > lat[11] * 4.0, "128 MB much slower: {lat:?}");
        // Prediction < latency (network component).
        let prd = parse_col(&csv, 3);
        for (l, p) in lat.iter().zip(&prd) {
            assert!(l > p);
        }
        std::fs::remove_dir_all(c.out_dir).ok();
    }

    #[test]
    fn resnext_missing_small_memory_points() {
        let c = ctx();
        run_warm(&c, "resnext50", "figtest3").unwrap();
        let csv = std::fs::read_to_string(c.out_dir.join("figtest3.csv")).unwrap();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), 12);
        // 128..448 not deployable (peak 429 MB).
        assert!(rows[0].contains("-"), "128 MB missing");
        assert!(rows[2].contains("-"), "384 MB missing");
        assert!(!rows[3].contains(",-"), "512 MB present: {}", rows[3]);
        std::fs::remove_dir_all(c.out_dir).ok();
    }

    #[test]
    fn cost_non_monotone_and_top_end_expensive() {
        // The paper's cost findings (§3.2): total cost "does not
        // necessarily increase with the memory size" (the shorter
        // execution offsets the higher unit price at some steps), but
        // past the latency plateau (1024->1536 MB) cost strictly rises.
        let c = ctx();
        run_warm(&c, "squeezenet", "figtest-cost").unwrap();
        let csv = std::fs::read_to_string(c.out_dir.join("figtest-cost.csv")).unwrap();
        let cost = parse_col(&csv, 5);
        assert_eq!(cost.len(), 12);
        let non_monotone = cost.windows(2).any(|w| w[1] < w[0]);
        assert!(non_monotone, "some step got cheaper: {cost:?}");
        let min = cost.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(cost[11] > min * 1.2, "1536 MB costs more than the optimum: {cost:?}");
        std::fs::remove_dir_all(c.out_dir).ok();
    }
}
