//! Tabular experiment output: aligned console print + CSV files.

use anyhow::{Context, Result};
use std::path::Path;

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Aligned console rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("{}\n", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.headers.join(","));
        s.push('\n');
        for row in &self.rows {
            let escaped: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            s.push_str(&escaped.join(","));
            s.push('\n');
        }
        s
    }
}

/// Write a table as `<out_dir>/<name>.csv`.
pub fn write_csv(table: &Table, out_dir: &Path, name: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let path = out_dir.join(format!("{name}.csv"));
    std::fs::write(&path, table.to_csv())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// `x.yz` seconds formatting used across experiment rows.
pub fn secs(v: f64) -> String {
    format!("{v:.3}")
}

/// Cost in dollars x 1000, as the paper plots it.
pub fn cost_x1000(v: f64) -> String {
    format!("{:.4}", v * 1000.0)
}

/// `xx.x%` share formatting (SLA-violation rates, cold fractions,
/// batched-request shares).
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("t", &["mem", "latency"]);
        t.row(vec!["128".into(), "1.52".into()]);
        t.row(vec!["1536".into(), "0.12".into()]);
        t
    }

    #[test]
    fn render_aligns() {
        let r = sample().render();
        assert!(r.contains("mem  latency"));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["x,y".into()]);
        t.row(vec!["q\"u".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"u\""));
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("lambdaserve-report-test");
        write_csv(&sample(), &dir, "fig1").unwrap();
        let content = std::fs::read_to_string(dir.join("fig1.csv")).unwrap();
        assert!(content.starts_with("mem,latency"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn formatters() {
        assert_eq!(secs(1.23456), "1.235");
        assert_eq!(cost_x1000(0.0000015), "0.0015");
        assert_eq!(pct(0.051), "5.1%");
        assert_eq!(pct(0.0), "0.0%");
    }
}
