//! Table 1: AWS Lambda price per 100 ms for each memory size.

use super::report::{write_csv, Table};
use super::ExpCtx;
use crate::configparse::MEMORY_SIZES_2017;
use anyhow::Result;

pub fn run_table1(ctx: &ExpCtx) -> Result<()> {
    let mut t = Table::new(
        "Table 1: AWS Lambda price per 100ms by memory size (2017)",
        &["Memory (MB)", "Price per 100ms ($)"],
    );
    for mem in MEMORY_SIZES_2017 {
        let p = ctx.config.pricing.price_per_unit(mem)?;
        t.row(vec![mem.to_string(), format!("{p:.9}")]);
    }
    t.print();
    write_csv(&t, &ctx.out_dir, "table1")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::EngineKind;

    #[test]
    fn reproduces_paper_rows() {
        let mut ctx = ExpCtx::new(EngineKind::Mock);
        ctx.out_dir = std::env::temp_dir().join("lambdaserve-table1-test");
        run_table1(&ctx).unwrap();
        let csv = std::fs::read_to_string(ctx.out_dir.join("table1.csv")).unwrap();
        // Spot-check the paper's first and last rows.
        assert!(csv.contains("128,0.000000208"));
        assert!(csv.contains("1536,0.000002501"));
        assert_eq!(csv.lines().count(), 13);
        std::fs::remove_dir_all(ctx.out_dir).ok();
    }
}
