//! Figures 4-6: cold function execution across memory sizes.
//!
//! Method (paper §3.1, §3.3): 5 sequential requests separated by
//! 10-minute gaps. The gaps exceed the keep-alive TTL, so every
//! request cold-starts. The gaps run on the manual clock (instant);
//! the model-load work is real. One discarded warm-up request per
//! (model, memory) absorbs the per-process compile (MXNet in the paper
//! had no compile step; see DESIGN.md §Substitutions).

use super::report::{cost_x1000, secs, write_csv, Table};
use super::ExpCtx;
use crate::configparse::MEMORY_SIZES_2017;
use crate::platform::Invoker;
use crate::stats::mean_ci95;
use crate::util::ManualClock;
use crate::workload::{run_closed_loop, ColdProbe};
use anyhow::Result;
use std::time::Duration;

pub fn run_cold(ctx: &ExpCtx, model: &str, name: &str) -> Result<()> {
    let engine = ctx.build_engine()?;
    let mut t = Table::new(
        &format!("{name}: cold execution ({model}); mean over 5 requests at 10-min gaps [95% CI]"),
        &["Memory (MB)", "Latency (s)", "±CI", "Prediction (s)", "±CI", "Cost x1000 ($)"],
    );

    for mem in MEMORY_SIZES_2017 {
        let clock = ManualClock::new();
        let platform = Invoker::new(ctx.config.clone(), engine.clone(), clock);
        if platform.deploy("f", model, "pallas", mem).is_err() {
            t.row(vec![mem.to_string(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into()]);
            continue;
        }
        // Discarded warm-up: absorbs the one-time artifact compile so
        // all measured cold starts pay the same (real) model load.
        let _ = platform.invoke("f", 0);
        platform.evict_all();
        platform.billing.reset();
        platform.metrics.reset();

        let probe = ColdProbe { requests: 5, gap: Duration::from_secs(600) };
        let report = run_closed_loop(&platform, "f", &probe, ctx.config.seed ^ mem as u64);
        assert_eq!(report.cold_count(), report.ok_samples().len(), "all requests cold");
        let (lat, lat_ci) = mean_ci95(&report.latencies_s());
        let (prd, prd_ci) = mean_ci95(&report.predicts_s());
        t.row(vec![
            mem.to_string(),
            secs(lat),
            secs(lat_ci),
            secs(prd),
            secs(prd_ci),
            cost_x1000(report.total_cost()),
        ]);
    }
    t.print();
    write_csv(&t, &ctx.out_dir, name)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::EngineKind;

    fn parse_col(csv: &str, col: usize) -> Vec<f64> {
        csv.lines()
            .skip(1)
            .filter_map(|l| l.split(',').nth(col))
            .filter_map(|v| v.parse().ok())
            .collect()
    }

    #[test]
    fn cold_latency_exceeds_prediction_and_decreases() {
        let mut c = ExpCtx::new(EngineKind::Mock);
        c.out_dir = std::env::temp_dir().join(format!("lambdaserve-cold-{}", std::process::id()));
        run_cold(&c, "squeezenet", "figtest4").unwrap();
        let csv = std::fs::read_to_string(c.out_dir.join("figtest4.csv")).unwrap();
        let lat = parse_col(&csv, 1);
        let prd = parse_col(&csv, 3);
        assert_eq!(lat.len(), 12);
        // Cold latency dominated by bootstrap: much larger than predict.
        for (l, p) in lat.iter().zip(&prd) {
            assert!(*l > p + 0.2, "cold overhead visible: {l} vs {p}");
        }
        // Decreasing with memory but flatter than warm: the
        // memory-independent sandbox component stays.
        assert!(lat[0] > lat[11], "{lat:?}");
        let warm_ratio = prd[0] / prd[11];
        let cold_ratio = lat[0] / lat[11];
        assert!(cold_ratio < warm_ratio, "cold curve flatter: {cold_ratio} < {warm_ratio}");
        std::fs::remove_dir_all(c.out_dir).ok();
    }
}
