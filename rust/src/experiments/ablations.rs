//! Ablations beyond the paper's figures, motivated by its discussion:
//!
//! * `abl-keepalive` — keep-alive TTL sweep: cold-start fraction and
//!   SLA-violation rate vs TTL (§3.5/§5: bimodal latency "can risk the
//!   adherence to SLAs"; §5 asks for a declarative keep-warm knob).
//! * `abl-provisioned` — serverless vs an always-on dedicated server:
//!   cost crossover as a function of sustained request rate (§4/§5:
//!   dedicated serving systems "are not designed to minimize cost when
//!   demand is changing"; §5 suggests VM+serverless mixes).
//! * `abl-memopt` — the §5 "future work" tool: recommend a memory size
//!   for a latency target or a cost budget from measured sweeps.
//! * `abl-kernel` — L1 ablation: Pallas-kernel artifacts vs pure-XLA
//!   reference artifacts (requires the PJRT engine).

use super::report::{secs, write_csv, Table};
use super::{EngineKind, ExpCtx};
use crate::configparse::MEMORY_SIZES_2017;
use crate::platform::Invoker;
use crate::stats::mean_ci95;
use crate::util::ManualClock;
use crate::workload::{run_closed_loop, DiurnalTrace, PoissonArrivals, WarmProbe};
use anyhow::Result;
use std::time::Duration;

/// Keep-alive TTL sweep under sparse Poisson traffic (mean gap 5 min):
/// TTLs below the typical gap force mostly-cold behaviour.
pub fn run_keepalive_ablation(ctx: &ExpCtx) -> Result<()> {
    let engine = ctx.build_engine()?;
    let sla = Duration::from_secs(2);
    let mut t = Table::new(
        "abl-keepalive: cold fraction & SLA(2s) violations vs keep-alive TTL \
         (squeezenet @1024MB, Poisson 1 req/5min, 8h)",
        &["TTL (min)", "Cold frac", "Mean lat (s)", "p99 (s)", "SLA viol frac"],
    );
    for ttl_min in [0u64, 1, 5, 10, 20, 30] {
        let mut config = ctx.config.clone();
        config.keep_alive_s = ttl_min as f64 * 60.0;
        let clock = ManualClock::new();
        let platform = Invoker::new(config, engine.clone(), clock);
        platform.deploy("f", "squeezenet", "pallas", 1024)?;
        let sched = PoissonArrivals {
            rps: 1.0 / 300.0,
            duration: Duration::from_secs(8 * 3600),
            seed: ctx.config.seed,
        };
        let report = run_closed_loop(&platform, "f", &sched, ctx.config.seed ^ ttl_min);
        let ok = report.ok_samples().len().max(1);
        let lats = report.latencies_s();
        let (mean, _) = mean_ci95(&lats);
        let mut sorted = lats.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = sorted
            .get(((0.99 * sorted.len() as f64).ceil() as usize).saturating_sub(1))
            .copied()
            .unwrap_or(0.0);
        let viol = lats.iter().filter(|l| **l > sla.as_secs_f64()).count() as f64 / ok as f64;
        t.row(vec![
            ttl_min.to_string(),
            format!("{:.2}", report.cold_count() as f64 / ok as f64),
            secs(mean),
            secs(p99),
            format!("{viol:.2}"),
        ]);
    }
    t.print();
    write_csv(&t, &ctx.out_dir, "abl-keepalive")?;
    Ok(())
}

/// Serverless vs dedicated: cost/hour as sustained request rate grows,
/// under a *diurnal + bursty* trace (the paper's "quickly changing or
/// even unpredictable" demand). Dedicated baseline: always-on instances
/// at `DEDICATED_PER_HOUR` each, provisioned for the PEAK rate (no
/// cold starts, no throttling — but you pay for idle troughs).
pub fn run_provisioned(ctx: &ExpCtx) -> Result<()> {
    const DEDICATED_PER_HOUR: f64 = 0.10; // m4.large-class, 2017
    // One dedicated m4.large-class instance (2 vCPUs) sustains ~16
    // req/s of squeezenet at full CPU speed (~0.12 s/req per core).
    // This is what makes dedicated ~2x cheaper per request at full
    // utilization: Lambda bills a 1024 MB container (0.57 vCPU-share)
    // in rounded 100 ms units, so its effective $/vCPU-hour is higher.
    const DEDICATED_CAPACITY_RPS: f64 = 16.0;
    let engine = ctx.build_engine()?;
    let mut t = Table::new(
        "abl-provisioned: serverless vs dedicated $/h — flat vs diurnal+bursty \
         traffic (squeezenet @1024MB, 1h per point; dedicated sized for peak)",
        &["Mean (req/min)", "Shape", "Peak (req/s)", "Serverless ($/h)", "Dedicated ($/h)", "Cheaper"],
    );
    let mut flat_crossover = false;
    let mut bursty_dedicated_wins = 0usize;
    for per_min in [1u64, 6, 30, 60, 300, 900, 3600] {
        let mean_rps = per_min as f64 / 60.0;
        for shape in ["flat", "bursty"] {
            let clock = ManualClock::new();
            let platform = Invoker::new(ctx.config.clone(), engine.clone(), clock);
            platform.deploy("f", "squeezenet", "pallas", 1024)?;
            let (report, peak_rps) = if shape == "flat" {
                let sched = PoissonArrivals {
                    rps: mean_rps,
                    duration: Duration::from_secs(3600),
                    seed: ctx.config.seed ^ per_min,
                };
                (run_closed_loop(&platform, "f", &sched, ctx.config.seed ^ per_min), mean_rps)
            } else {
                let sched =
                    DiurnalTrace::compressed_day(mean_rps, ctx.config.seed ^ per_min);
                let a = (sched.swing - 1.0) / (sched.swing + 1.0);
                let peak = sched.mean_rps * (1.0 + a) * sched.burst_factor;
                (run_closed_loop(&platform, "f", &sched, ctx.config.seed ^ per_min), peak)
            };
            let serverless = report.total_cost();
            let dedicated =
                (peak_rps / DEDICATED_CAPACITY_RPS).ceil().max(1.0) * DEDICATED_PER_HOUR;
            let cheaper = if serverless < dedicated { "serverless" } else { "dedicated" };
            if cheaper == "dedicated" {
                if shape == "flat" {
                    flat_crossover = true;
                } else {
                    bursty_dedicated_wins += 1;
                }
            }
            t.row(vec![
                per_min.to_string(),
                shape.to_string(),
                format!("{peak_rps:.1}"),
                format!("{serverless:.4}"),
                format!("{dedicated:.4}"),
                cheaper.to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "shape: flat sustained traffic crosses over to dedicated ({}); bursty \
         peak-provisioned demand stays serverless ({} dedicated wins) — the \
         paper's §4 cost argument.",
        if flat_crossover { "yes" } else { "no" },
        bursty_dedicated_wins
    );
    write_csv(&t, &ctx.out_dir, "abl-provisioned")?;
    Ok(())
}

/// §5 future-work tool: run the warm sweep, then recommend (a) the
/// cheapest memory meeting a latency target and (b) the fastest memory
/// within a cost budget; flag the paper's 1024->1536 "paying more for
/// nothing" region.
pub fn run_memopt(ctx: &ExpCtx) -> Result<()> {
    let engine = ctx.build_engine()?;
    let model = "squeezenet";
    let mut rows: Vec<(u32, f64, f64)> = Vec::new(); // (mem, lat, cost)
    for mem in MEMORY_SIZES_2017 {
        let clock = ManualClock::new();
        let platform = Invoker::new(ctx.config.clone(), engine.clone(), clock);
        if platform.deploy("f", model, "pallas", mem).is_err() {
            continue;
        }
        let probe = WarmProbe { requests: ctx.reps, interval: Duration::from_secs(1) };
        let report = run_closed_loop(&platform, "f", &probe, ctx.config.seed ^ mem as u64);
        let (lat, _) = mean_ci95(&report.latencies_s());
        rows.push((mem, lat, report.total_cost() / report.ok_samples().len().max(1) as f64));
    }

    let mut t = Table::new(
        &format!("abl-memopt: memory recommendation ({model}, warm)"),
        &["Memory (MB)", "Latency (s)", "Cost/req ($)", "Note"],
    );
    let latency_target = 1.0;
    let best_cheap = rows
        .iter()
        .filter(|(_, lat, _)| *lat <= latency_target)
        .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
    let best_fast = rows.iter().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    // "Knee": smallest memory whose latency is within 10% of the best.
    let knee = best_fast.and_then(|bf| {
        rows.iter().find(|(_, lat, _)| *lat <= bf.1 * 1.10)
    });
    for (mem, lat, cost) in &rows {
        let mut notes = Vec::new();
        if best_cheap.map(|r| r.0) == Some(*mem) {
            notes.push(format!("cheapest under {latency_target:.1}s"));
        }
        if best_fast.map(|r| r.0) == Some(*mem) {
            notes.push("fastest".into());
        }
        if knee.map(|r| r.0) == Some(*mem) {
            notes.push("recommended (knee)".into());
        }
        t.row(vec![mem.to_string(), secs(*lat), format!("{cost:.8}"), notes.join("; ")]);
    }
    t.print();
    if let (Some(k), Some(f)) = (knee, best_fast) {
        if k.0 < f.0 {
            println!(
                "note: {} MB reaches within 10% of the {} MB latency — the paper's \
                 'more memory buys nothing' region starts at {} MB",
                k.0, f.0, k.0
            );
        }
    }
    write_csv(&t, &ctx.out_dir, "abl-memopt")?;
    Ok(())
}

/// L1 kernel ablation: compare warm prediction times between the
/// Pallas-kernel artifact and the pure-XLA reference artifact.
pub fn run_kernel_ablation(ctx: &ExpCtx) -> Result<()> {
    if ctx.engine_kind != EngineKind::Pjrt {
        println!("abl-kernel requires --engine pjrt (real artifacts); skipping");
        return Ok(());
    }
    let engine = ctx.build_engine()?;
    let mut t = Table::new(
        "abl-kernel: Pallas kernel vs pure-XLA reference (warm predict @1536MB, full CPU)",
        &["Model", "Variant", "Predict mean (s)", "±CI", "Slowdown"],
    );
    for model in super::PAPER_MODELS {
        let mut base = None;
        for variant in ["ref", "pallas"] {
            let clock = ManualClock::new();
            let platform = Invoker::new(ctx.config.clone(), engine.clone(), clock);
            platform.deploy("f", model, variant, 1536)?;
            let probe = WarmProbe { requests: ctx.reps.min(10), interval: Duration::from_millis(10) };
            let report = run_closed_loop(&platform, "f", &probe, ctx.config.seed);
            let (prd, ci) = mean_ci95(&report.predicts_s());
            let slowdown = match base {
                None => {
                    base = Some(prd);
                    "1.00x".to_string()
                }
                Some(b) => format!("{:.2}x", prd / b),
            };
            t.row(vec![model.into(), variant.into(), secs(prd), secs(ci), slowdown]);
        }
    }
    t.print();
    write_csv(&t, &ctx.out_dir, "abl-kernel")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(tag: &str) -> ExpCtx {
        let mut c = ExpCtx::new(EngineKind::Mock);
        c.out_dir = std::env::temp_dir().join(format!("lambdaserve-abl-{tag}-{}", std::process::id()));
        c.reps = 8;
        c
    }

    #[test]
    fn keepalive_cold_fraction_decreases_with_ttl() {
        let c = ctx("ka");
        run_keepalive_ablation(&c).unwrap();
        let csv = std::fs::read_to_string(c.out_dir.join("abl-keepalive.csv")).unwrap();
        let cold: Vec<f64> = csv
            .lines()
            .skip(1)
            .filter_map(|l| l.split(',').nth(1))
            .filter_map(|v| v.parse().ok())
            .collect();
        assert_eq!(cold.len(), 6);
        assert!(cold[0] > 0.95, "TTL=0 always cold: {cold:?}");
        assert!(cold[5] < cold[0], "long TTL reduces cold starts: {cold:?}");
        // SLA violations track cold fraction (bimodality claim).
        let viol: Vec<f64> = csv
            .lines()
            .skip(1)
            .filter_map(|l| l.split(',').nth(4))
            .filter_map(|v| v.parse().ok())
            .collect();
        assert!(viol[0] > viol[5]);
        std::fs::remove_dir_all(c.out_dir).ok();
    }

    #[test]
    fn provisioned_crossover_direction() {
        let c = ctx("prov");
        run_provisioned(&c).unwrap();
        let csv = std::fs::read_to_string(c.out_dir.join("abl-provisioned.csv")).unwrap();
        let flat: Vec<&str> = csv.lines().filter(|l| l.contains(",flat,")).collect();
        let bursty: Vec<&str> = csv.lines().filter(|l| l.contains(",bursty,")).collect();
        // Sparse traffic: serverless wins under both shapes.
        assert!(flat[0].ends_with("serverless"), "{}", flat[0]);
        assert!(bursty[0].ends_with("serverless"), "{}", bursty[0]);
        // Sustained flat traffic crosses over to dedicated...
        assert!(flat.last().unwrap().ends_with("dedicated"), "{}", flat.last().unwrap());
        // ...but peak-provisioned bursty demand keeps serverless ahead
        // far longer: strictly fewer dedicated wins than flat.
        let wins = |rows: &[&str]| rows.iter().filter(|l| l.ends_with("dedicated")).count();
        assert!(wins(&bursty) < wins(&flat), "bursty favors serverless");
        std::fs::remove_dir_all(c.out_dir).ok();
    }

    #[test]
    fn memopt_emits_recommendation() {
        let c = ctx("memopt");
        run_memopt(&c).unwrap();
        let csv = std::fs::read_to_string(c.out_dir.join("abl-memopt.csv")).unwrap();
        assert!(csv.contains("recommended (knee)"));
        assert!(csv.contains("fastest"));
        std::fs::remove_dir_all(c.out_dir).ok();
    }

    #[test]
    fn kernel_ablation_skips_on_mock() {
        let c = ctx("kern");
        run_kernel_ablation(&c).unwrap(); // prints skip note, no panic
    }
}
