//! `lambdaserve` launcher.
//!
//! Subcommands:
//!
//! * `serve`       — start the HTTP gateway on the live platform
//! * `deploy`      — deploy: against a remote gateway with `--addr`
//!                   (v2 API), or validate offline without it
//! * `invoke`      — invoke: against a remote gateway with `--addr`
//!                   (sync or `--mode async`), or one-shot local
//! * `undeploy`    — remove a function from a remote gateway
//! * `stats`       — per-function stats from a remote gateway
//! * `trace`       — span waterfall for one invocation (`--id`) or a
//!                   function's retained exemplars (`--function`)
//! * `experiment`  — run a paper experiment by id (`table1`, `fig1`..
//!                   `fig10`, `abl-*`, or `all`)
//! * `price-table` — print Table 1
//! * `models`      — list the AOT model zoo

use anyhow::{bail, Result};
use lambdaserve::cliparse::Command;
use lambdaserve::configparse::PlatformConfig;
use lambdaserve::experiments::{self, EngineKind, ExpCtx};
use lambdaserve::gateway::{ApiClient, DeploySpec, Gateway};
use lambdaserve::platform::Invoker;
use lambdaserve::runtime::{Engine, MockEngine, PjrtEngine, Zoo};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "usage: lambdaserve <serve|deploy|invoke|undeploy|stats|trace|loadgen|experiment|price-table|models> [flags]\n\
     run `lambdaserve <cmd> --help` for per-command flags"
        .to_string()
}

fn load_config(args: &lambdaserve::cliparse::Args) -> Result<PlatformConfig> {
    match args.get("config") {
        Some(path) => PlatformConfig::load(Path::new(path)),
        None => Ok(PlatformConfig::default()),
    }
}

fn build_engine(kind: &str, config: &PlatformConfig, shards: usize) -> Result<Arc<dyn Engine>> {
    // The ladder top lives on the concrete engine types, so it must be
    // set before the Arc<dyn Engine> erasure.
    match kind {
        "pjrt" => {
            let engine = PjrtEngine::new(Path::new(&config.artifacts_dir), shards)?;
            engine.set_batch_kernel_max(config.batch_kernel_max);
            Ok(Arc::new(engine))
        }
        "mock" => {
            let engine = MockEngine::paper_zoo();
            engine.set_batch_kernel_max(config.batch_kernel_max);
            Ok(Arc::new(engine))
        }
        other => bail!("unknown engine {other:?} (pjrt|mock)"),
    }
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "serve" => cmd_serve(rest),
        "deploy" => cmd_deploy(rest),
        "invoke" => cmd_invoke(rest),
        "undeploy" => cmd_undeploy(rest),
        "stats" => cmd_stats(rest),
        "trace" => cmd_trace(rest),
        "loadgen" => cmd_loadgen(rest),
        "experiment" => cmd_experiment(rest),
        "price-table" => cmd_price_table(rest),
        "models" => cmd_models(rest),
        "--help" | "help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "start the HTTP gateway")
        .flag("addr", "bind address", Some("127.0.0.1:8080"))
        .flag("config", "platform config TOML", None)
        .flag("engine", "pjrt | mock", Some("pjrt"))
        .flag("shards", "engine shards (compute parallelism)", Some("2"))
        .flag("threads", "gateway worker threads", Some("16"))
        .flag(
            "maintainer-interval",
            "pool maintainer tick, seconds (sweep + min_warm top-up; 0 disables)",
            None,
        )
        .flag(
            "queue-capacity",
            "admission: default per-function dispatch-queue bound (0 = never park)",
            None,
        )
        .flag(
            "queue-deadline-ms",
            "admission: default wait deadline before 503, milliseconds (0 = try once)",
            None,
        )
        .flag(
            "max-batch-size",
            "micro-batching: default max requests coalesced into one forward pass (1 = off)",
            None,
        )
        .flag(
            "batch-window-ms",
            "micro-batching: default window a batch leader collects followers, milliseconds",
            None,
        )
        .flag(
            "batch-kernel-max",
            "top rung of the batch-N kernel ladder, power of two (1 = batch-1 executables only)",
            None,
        )
        .flag(
            "pool-shards",
            "warm-pool lock shards, functions hash-partitioned across them (1 = single lock)",
            None,
        )
        .bool_flag(
            "snapshot",
            "enable snapshot/restore cold-start mitigation platform-wide (overrides config)",
        )
        .bool_flag("no-snapshot", "disable snapshot/restore platform-wide (overrides config)")
        .bool_flag(
            "adaptive",
            "enable the adaptive hot-path controllers platform-wide (overrides config)",
        )
        .bool_flag("no-adaptive", "disable the adaptive controllers platform-wide (overrides config)")
        .flag(
            "slo-target-ms",
            "adaptive: default per-function response SLO budget the controllers defend (ms)",
            None,
        )
        .flag(
            "deploy",
            "comma list of name:model:mem to deploy at boot, e.g. sq:squeezenet:1024",
            None,
        );
    if argv.iter().any(|a| a == "--help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let mut config = load_config(&args)?;
    if let Some(v) = args.get_f64("maintainer-interval")? {
        config.maintainer_interval_s = v;
    }
    if let Some(v) = args.get_u64("queue-capacity")? {
        config.queue_capacity = v as usize;
    }
    if let Some(v) = args.get_u64("queue-deadline-ms")? {
        config.queue_deadline_ms = v;
    }
    if let Some(v) = args.get_u64("max-batch-size")? {
        config.max_batch_size = v as usize;
    }
    if let Some(v) = args.get_u64("batch-window-ms")? {
        config.batch_window_ms = v;
    }
    if let Some(v) = args.get_u64("batch-kernel-max")? {
        config.batch_kernel_max = v as usize;
    }
    if let Some(v) = args.get_u64("pool-shards")? {
        config.pool_shards = v as usize;
    }
    if args.get_bool("snapshot") && args.get_bool("no-snapshot") {
        bail!("--snapshot and --no-snapshot are mutually exclusive");
    }
    if args.get_bool("snapshot") {
        config.snapshot.enabled = true;
    }
    if args.get_bool("no-snapshot") {
        config.snapshot.enabled = false;
    }
    if args.get_bool("adaptive") && args.get_bool("no-adaptive") {
        bail!("--adaptive and --no-adaptive are mutually exclusive");
    }
    if args.get_bool("adaptive") {
        config.policy.enabled = true;
    }
    if args.get_bool("no-adaptive") {
        config.policy.enabled = false;
    }
    if let Some(v) = args.get_u64("slo-target-ms")? {
        config.policy.slo_target_ms = v;
    }
    // Same rules as the TOML path (maintainer range, deadline cap,
    // batch-size floor, restore bandwidth).
    config.validate()?;
    // Non-fatal misconfigurations (e.g. adaptive controllers enabled
    // with nothing for them to steer) go to stderr, not to a bail.
    for w in config.warnings() {
        eprintln!("warning: {w}");
    }
    let shards = args.get_u64("shards")?.unwrap_or(2) as usize;
    let engine = build_engine(args.get_or("engine", "pjrt"), &config, shards)?;
    let platform = Arc::new(Invoker::live(config, engine));

    if let Some(deploys) = args.get_list("deploy") {
        for d in deploys {
            let parts: Vec<&str> = d.split(':').collect();
            if parts.len() != 3 {
                bail!("--deploy entries are name:model:mem, got {d:?}");
            }
            let mem: u32 = parts[2].parse()?;
            platform.deploy(parts[0], parts[1], "pallas", mem)?;
            println!("deployed {} = {} @ {} MB", parts[0], parts[1], mem);
        }
    }

    let threads = args.get_u64("threads")?.unwrap_or(16) as usize;
    let interval = platform.config().maintainer_interval_s;
    let (queue_capacity, queue_deadline_ms) =
        (platform.config().queue_capacity, platform.config().queue_deadline_ms);
    let (max_batch_size, batch_window_ms) =
        (platform.config().max_batch_size, platform.config().batch_window_ms);
    let snapshot_cfg = platform.config().snapshot.clone();
    let policy_cfg = platform.config().policy.clone();
    let gw = Gateway::bind(args.get_or("addr", "127.0.0.1:8080"), threads, platform)?;
    println!("lambdaserve gateway listening on http://{}", gw.local_addr());
    if interval > 0.0 {
        println!("  pool maintainer: sweep + min_warm top-up every {interval:.1}s");
    } else {
        println!("  pool maintainer: disabled (min_warm pools decay past the keep-alive TTL)");
    }
    if queue_capacity > 0 {
        println!(
            "  admission: per-function queue of {queue_capacity}, {queue_deadline_ms} ms deadline \
             (then 503 + Retry-After)"
        );
    } else {
        println!("  admission: parking disabled (a capacity shortage is an immediate 503)");
    }
    if max_batch_size > 1 {
        println!(
            "  micro-batching: up to {max_batch_size} requests per forward pass, \
             {batch_window_ms} ms collection window"
        );
    } else {
        println!("  micro-batching: off (max_batch_size 1; enable per function or via config)");
    }
    if snapshot_cfg.enabled {
        println!(
            "  snapshots: cold provisions restore from checkpoints ({} MB store, \
             {:.0} MB/s restore, capture {:?})",
            snapshot_cfg.capacity_bytes >> 20,
            snapshot_cfg.restore_bw / 1e6,
            snapshot_cfg.capture_policy
        );
    } else {
        println!("  snapshots: off (enable per function or with --snapshot)");
    }
    if policy_cfg.enabled {
        println!(
            "  adaptive: SLO {} ms, batch window up to {} ms, forecast pre-warm up to {}",
            policy_cfg.slo_target_ms, policy_cfg.window_cap_ms, policy_cfg.max_prewarm
        );
    } else {
        println!("  adaptive: off (enable per function or with --adaptive)");
    }
    println!("  v2: POST /v2/functions  POST /v2/functions/<fn>/invocations[?mode=async]");
    println!("  v1: GET /v1/invoke/<function>   POST /v1/functions?name=&model=&mem=");
    println!("  reference: API.md");
    gw.serve()
}

fn cmd_deploy(argv: &[String]) -> Result<()> {
    let cmd = Command::new("deploy", "deploy to a remote gateway (--addr) or validate offline")
        .flag("addr", "remote gateway address (omit for offline validation)", None)
        .flag("name", "function name", Some("fn"))
        .flag("model", "zoo model", Some("squeezenet"))
        .flag("variant", "artifact variant", Some("pallas"))
        .flag("mem", "memory MB", Some("1024"))
        .flag("min-warm", "containers to keep pre-warmed", Some("0"))
        .flag("max-concurrency", "per-function in-flight cap", None)
        .flag("queue-capacity", "per-function dispatch-queue bound override", None)
        .flag("queue-deadline-ms", "per-function dispatch deadline override (ms)", None)
        .flag("max-batch-size", "per-function micro-batch size override (1 = off)", None)
        .flag("batch-window-ms", "per-function batch collection window override (ms)", None)
        .bool_flag("snapshot", "force snapshot/restore ON for this function")
        .bool_flag("no-snapshot", "force snapshot/restore OFF for this function")
        .flag("slo-target-ms", "per-function response SLO budget override (ms)", None)
        .bool_flag("adaptive", "force the adaptive controllers ON for this function")
        .bool_flag("no-adaptive", "force the adaptive controllers OFF for this function")
        .flag("config", "platform config TOML", None)
        .flag("engine", "pjrt | mock", Some("mock"));
    if argv.iter().any(|a| a == "--help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    if let Some(addr) = args.get("addr") {
        // Remote: v2 API through the typed client SDK.
        let api = ApiClient::new(addr);
        let mut spec = DeploySpec::new(args.get_or("name", "fn"), args.get_or("model", "squeezenet"))
            .variant(args.get_or("variant", "pallas"))
            .memory_mb(args.get_u64("mem")?.unwrap_or(1024) as u32)
            .min_warm(args.get_u64("min-warm")?.unwrap_or(0) as usize);
        if let Some(cap) = args.get_u64("max-concurrency")? {
            spec = spec.max_concurrency(cap as usize);
        }
        if let Some(q) = args.get_u64("queue-capacity")? {
            spec = spec.queue_capacity(q as usize);
        }
        if let Some(d) = args.get_u64("queue-deadline-ms")? {
            spec = spec.queue_deadline_ms(d);
        }
        if let Some(b) = args.get_u64("max-batch-size")? {
            spec = spec.max_batch_size(b as usize);
        }
        if let Some(w) = args.get_u64("batch-window-ms")? {
            spec = spec.batch_window_ms(w);
        }
        if args.get_bool("snapshot") && args.get_bool("no-snapshot") {
            bail!("--snapshot and --no-snapshot are mutually exclusive");
        }
        if args.get_bool("snapshot") {
            spec = spec.snapshot(true);
        }
        if args.get_bool("no-snapshot") {
            spec = spec.snapshot(false);
        }
        if let Some(t) = args.get_u64("slo-target-ms")? {
            spec = spec.slo_target_ms(t);
        }
        if args.get_bool("adaptive") && args.get_bool("no-adaptive") {
            bail!("--adaptive and --no-adaptive are mutually exclusive");
        }
        if args.get_bool("adaptive") {
            spec = spec.adaptive(true);
        }
        if args.get_bool("no-adaptive") {
            spec = spec.adaptive(false);
        }
        let f = api.deploy(&spec)?;
        println!(
            "deployed {} -> {} ({}) @ {} MB (min_warm={}, max_concurrency={}, \
             queue_capacity={}, queue_deadline_ms={}, max_batch_size={}, \
             batch_window_ms={}, snapshot={}, slo_target_ms={}, adaptive={}, warm={})",
            f.name,
            f.model,
            f.variant,
            f.memory_mb,
            f.min_warm,
            f.max_concurrency.map(|c| c.to_string()).unwrap_or_else(|| "none".into()),
            f.queue_capacity.map(|c| c.to_string()).unwrap_or_else(|| "default".into()),
            f.queue_deadline_ms.map(|c| c.to_string()).unwrap_or_else(|| "default".into()),
            f.max_batch_size.map(|c| c.to_string()).unwrap_or_else(|| "default".into()),
            f.batch_window_ms.map(|c| c.to_string()).unwrap_or_else(|| "default".into()),
            f.snapshot.map(|c| c.to_string()).unwrap_or_else(|| "default".into()),
            f.slo_target_ms.map(|c| c.to_string()).unwrap_or_else(|| "default".into()),
            f.adaptive.map(|c| c.to_string()).unwrap_or_else(|| "default".into()),
            f.warm_containers
        );
        return Ok(());
    }
    let config = load_config(&args)?;
    let engine = build_engine(args.get_or("engine", "mock"), &config, 1)?;
    let platform = Invoker::live(config, engine);
    let spec = platform.deploy(
        args.get_or("name", "fn"),
        args.get_or("model", "squeezenet"),
        args.get_or("variant", "pallas"),
        args.get_u64("mem")?.unwrap_or(1024) as u32,
    )?;
    println!(
        "ok: {} -> {} ({}) @ {} MB (peak requirement {} MB, package {:.1} MB)",
        spec.name,
        spec.model,
        spec.variant,
        spec.memory_mb,
        spec.peak_mem_mb,
        spec.package_bytes as f64 / 1e6
    );
    Ok(())
}

fn cmd_invoke(argv: &[String]) -> Result<()> {
    let cmd = Command::new("invoke", "invoke against a remote gateway (--addr) or one-shot local")
        .flag("addr", "remote gateway address (omit for local one-shot)", None)
        .flag("function", "remote function name", Some("fn"))
        .flag("mode", "remote invocation mode: sync | async", Some("sync"))
        .flag("model", "zoo model (local mode)", Some("squeezenet"))
        .flag("variant", "artifact variant (local mode)", Some("pallas"))
        .flag("mem", "memory MB (local mode)", Some("1024"))
        .flag("seed", "image seed", Some("1"))
        .flag("n", "number of requests", Some("2"))
        .flag("config", "platform config TOML", None)
        .flag("engine", "pjrt | mock", Some("pjrt"));
    if argv.iter().any(|a| a == "--help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    if let Some(addr) = args.get("addr") {
        let api = ApiClient::new(addr);
        let function = args.get_or("function", "fn");
        let n = args.get_u64("n")?.unwrap_or(2);
        let seed = args.get_u64("seed")?.unwrap_or(1);
        for i in 0..n {
            match args.get_or("mode", "sync") {
                "sync" => {
                    let r = api.invoke(function, Some(seed + i))?;
                    println!(
                        "[{}] top1={} p={:.4} start={} predict={:.3}s response={:.3}s billed={}ms cost=${:.8}",
                        i, r.top1, r.top_prob, r.start, r.predict_s, r.response_s, r.billed_ms,
                        r.cost_dollars
                    );
                }
                "async" => {
                    let id = api.invoke_async(function, Some(seed + i))?;
                    println!("[{i}] accepted: invocation {id}");
                    let done = api.wait_invocation(
                        &id,
                        Duration::from_millis(50),
                        Duration::from_secs(600),
                    )?;
                    match done.result {
                        Some(r) => println!(
                            "[{}] {} top1={} start={} response={:.3}s billed={}ms",
                            i, done.status, r.top1, r.start, r.response_s, r.billed_ms
                        ),
                        None => println!(
                            "[{}] {}: {}",
                            i,
                            done.status,
                            done.error.unwrap_or_default()
                        ),
                    }
                }
                other => bail!("unknown mode {other:?} (sync|async)"),
            }
        }
        return Ok(());
    }
    let config = load_config(&args)?;
    let engine = build_engine(args.get_or("engine", "pjrt"), &config, 1)?;
    let platform = Invoker::live(config, engine);
    let mem = args.get_u64("mem")?.unwrap_or(1024) as u32;
    platform.deploy("fn", args.get_or("model", "squeezenet"), args.get_or("variant", "pallas"), mem)?;
    let n = args.get_u64("n")?.unwrap_or(2);
    let seed = args.get_u64("seed")?.unwrap_or(1);
    for i in 0..n {
        let out = platform
            .invoke("fn", seed + i)
            .map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let r = &out.record;
        println!(
            "[{}] top1={} p={:.4} start={} predict={:.3}s response={:.3}s billed={}ms cost=${:.8}",
            i,
            out.prediction.top1,
            out.prediction.top_prob,
            r.start,
            r.predict.as_secs_f64(),
            r.response().as_secs_f64(),
            r.billed_ms,
            r.cost_dollars
        );
    }
    Ok(())
}

fn cmd_undeploy(argv: &[String]) -> Result<()> {
    let cmd = Command::new("undeploy", "remove a function from a remote gateway")
        .flag("addr", "gateway address", Some("127.0.0.1:8080"))
        .flag("name", "function name", Some("fn"));
    if argv.iter().any(|a| a == "--help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let api = ApiClient::new(args.get_or("addr", "127.0.0.1:8080"));
    let name = args.get_or("name", "fn");
    let reaped = api.undeploy(name)?;
    println!("undeployed {name} ({reaped} warm containers reaped)");
    Ok(())
}

fn cmd_stats(argv: &[String]) -> Result<()> {
    let cmd = Command::new("stats", "per-function stats from a remote gateway")
        .flag("addr", "gateway address", Some("127.0.0.1:8080"))
        .flag("function", "function name (omit to list all)", None);
    if argv.iter().any(|a| a == "--help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let api = ApiClient::new(args.get_or("addr", "127.0.0.1:8080"));
    let names: Vec<String> = match args.get("function") {
        Some(f) => vec![f.to_string()],
        None => api.functions()?.into_iter().map(|f| f.name).collect(),
    };
    if names.is_empty() {
        println!("no functions deployed");
        return Ok(());
    }
    for name in names {
        let s = api.stats(&name)?;
        println!(
            "{}: {} invocations ({} cold / {} restored / {} warm, {} throttled, \
             {} queue-expired), warm_containers={} queue_depth={}",
            s.function, s.invocations, s.cold_starts, s.restored_starts, s.warm_starts,
            s.throttled, s.queue_expired, s.warm_containers, s.queue_depth
        );
        println!(
            "  response mean={:.3}s p50={:.3}s p95={:.3}s p99={:.3}s predict mean={:.3}s",
            s.response_mean_s, s.response_p50_s, s.response_p95_s, s.response_p99_s,
            s.predict_mean_s
        );
        println!(
            "  queue wait p50={:.3}s p95={:.3}s p99={:.3}s",
            s.queue_wait_p50_s, s.queue_wait_p95_s, s.queue_wait_p99_s
        );
        if s.batched_requests > 0 || s.batch_size_p99 > 0 {
            println!(
                "  batching: {} batched ({:.0}% of requests), size p50={} p99={}, \
                 wait p50={:.3}s p99={:.3}s",
                s.batched_requests,
                s.batched_share * 100.0,
                s.batch_size_p50,
                s.batch_size_p99,
                s.batch_wait_p50_s,
                s.batch_wait_p99_s
            );
        }
        println!(
            "  cold p50={:.3}s p99={:.3}s | warm p50={:.3}s p99={:.3}s",
            s.response_cold_p50_s, s.response_cold_p99_s, s.response_warm_p50_s,
            s.response_warm_p99_s
        );
        if s.restored_starts > 0 || s.snapshot_captures > 0 {
            println!(
                "  snapshots: {} restored (p50={:.3}s p99={:.3}s, restore p99={:.3}s), \
                 {} hits / {} misses, {} captured, {} evicted, {:.1} MB stored",
                s.restored_starts,
                s.response_restored_p50_s,
                s.response_restored_p99_s,
                s.provision_restore_p99_s,
                s.snapshot_hits,
                s.snapshot_misses,
                s.snapshot_captures,
                s.snapshot_evictions,
                s.snapshot_bytes as f64 / 1e6
            );
        }
        println!(
            "  billed={}ms cost=${:.8} gb_seconds={:.4}",
            s.billed_ms_total, s.cost_dollars_total, s.gb_seconds_total
        );
    }
    Ok(())
}

/// Render one trace as the same ASCII waterfall shape
/// `platform::Trace::waterfall` produces, reconstructed from the
/// route JSON (offsets/durations in seconds).
fn render_waterfall(t: &lambdaserve::gateway::TraceView) -> String {
    const WIDTH: f64 = 40.0;
    let total = t
        .spans
        .iter()
        .map(|s| s.offset_s + s.duration_s)
        .fold(0.0_f64, f64::max)
        .max(1e-9);
    let mut out = format!(
        "{}  {}  {}  response {:.3}s{}{}\n",
        t.trace_id,
        t.function,
        t.kind,
        t.response_s,
        if t.slo_target_ms > 0 {
            format!("  slo {}ms {}", t.slo_target_ms, if t.slo_violation { "VIOLATED" } else { "ok" })
        } else {
            String::new()
        },
        match &t.error {
            Some(e) => format!("  error: {e}"),
            None => String::new(),
        },
    );
    for s in &t.spans {
        let pad = ((s.offset_s / total) * WIDTH).round() as usize;
        let bar = ((s.duration_s / total) * WIDTH)
            .round()
            .max(if s.duration_s > 0.0 { 1.0 } else { 0.0 }) as usize;
        let indent = if s.parent.is_some() { "    " } else { "  " };
        out.push_str(&format!(
            "{indent}{:<14} {}{} {:.3}s{}\n",
            s.stage,
            " ".repeat(pad.min(WIDTH as usize)),
            "#".repeat(bar.min(WIDTH as usize + 1)),
            s.duration_s,
            match &s.note {
                Some(n) => format!("  [{n}]"),
                None => String::new(),
            },
        ));
    }
    out
}

fn cmd_trace(argv: &[String]) -> Result<()> {
    let cmd = Command::new("trace", "span waterfalls from a remote gateway's trace ring")
        .flag("addr", "gateway address", Some("127.0.0.1:8080"))
        .flag("id", "trace id (tr-…) or async invocation id (inv-…)", None)
        .flag("function", "list retained exemplars for this function", None)
        .flag("kind", "exemplar filter: cold | restored | slow | error", None)
        .flag("limit", "max exemplars to list", Some("10"));
    if argv.iter().any(|a| a == "--help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let api = ApiClient::new(args.get_or("addr", "127.0.0.1:8080"));
    match (args.get("id"), args.get("function")) {
        (Some(id), _) => {
            let t = api.invocation_trace(id)?;
            print!("{}", render_waterfall(&t));
            if let Some(leader) = &t.shared_exec_with {
                println!("  (kernel_exec shared with leader trace {leader})");
            }
        }
        (None, Some(function)) => {
            let limit = args.get_u64("limit")?.map(|n| n as usize);
            let traces = api.function_traces(function, args.get("kind"), limit)?;
            if traces.is_empty() {
                println!("no retained traces for {function} (ring empty or all sampled out)");
                return Ok(());
            }
            for t in &traces {
                print!("{}", render_waterfall(t));
            }
            println!("{} trace(s)", traces.len());
        }
        (None, None) => bail!("pass --id <trace-or-invocation-id> or --function <name>"),
    }
    Ok(())
}

/// The JMeter analog: drive a REMOTE lambdaserve gateway over real
/// HTTP with one of the paper's schedules and report client-observed
/// latency statistics.
fn cmd_loadgen(argv: &[String]) -> Result<()> {
    use lambdaserve::exec::ThreadPool;
    use lambdaserve::httpd::http_get;
    use lambdaserve::stats::Summary;
    use lambdaserve::workload::{ColdProbe, PoissonArrivals, Schedule, StepRamp, WarmProbe};
    use std::sync::Mutex;
    use std::time::{Duration, Instant};

    let cmd = Command::new("loadgen", "HTTP load generator against a running gateway")
        .flag("addr", "gateway address", Some("127.0.0.1:8080"))
        .flag("function", "function route to invoke", Some("classify"))
        .flag("schedule", "warm | cold | step | poisson", Some("warm"))
        .flag("reps", "warm-probe request count", Some("25"))
        .flag("rps", "poisson rate (req/s)", Some("5"))
        .flag("duration", "poisson duration (s)", Some("30"))
        .flag("scale", "step-ramp scale factor", Some("0.2"))
        .flag("workers", "client concurrency", Some("64"))
        .flag("timeout", "per-request timeout (s)", Some("600"));
    if argv.iter().any(|a| a == "--help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let addr = args.get_or("addr", "127.0.0.1:8080").to_string();
    let function = args.get_or("function", "classify").to_string();
    let tmo = Duration::from_secs(args.get_u64("timeout")?.unwrap_or(600));

    let schedule: Box<dyn Schedule> = match args.get_or("schedule", "warm") {
        "warm" => Box::new(WarmProbe {
            requests: args.get_u64("reps")?.unwrap_or(25) as usize,
            interval: Duration::from_secs(1),
        }),
        // NOTE: remote cold probes wait REAL 10-minute gaps, exactly
        // like the paper's JMeter script did.
        "cold" => Box::new(ColdProbe::default()),
        "step" => Box::new(StepRamp::scaled(args.get_f64("scale")?.unwrap_or(0.2))),
        "poisson" => Box::new(PoissonArrivals {
            rps: args.get_f64("rps")?.unwrap_or(5.0),
            duration: Duration::from_secs(args.get_u64("duration")?.unwrap_or(30)),
            seed: 7,
        }),
        other => bail!("unknown schedule {other:?} (warm|cold|step|poisson)"),
    };

    let arrivals = schedule.arrivals();
    let discard = schedule.discard_prefix();
    println!(
        "loadgen: {} requests ({} discarded) against http://{addr}/v1/invoke/{function}",
        arrivals.len(),
        discard
    );
    let workers = args.get_u64("workers")?.unwrap_or(64) as usize;
    let pool = ThreadPool::new(workers, "loadgen");
    let results: Arc<Mutex<Vec<(bool, f64, bool)>>> = Arc::new(Mutex::new(Vec::new()));
    let t_start = Instant::now();
    let mut handles = Vec::new();
    for (i, at) in arrivals.iter().enumerate() {
        let elapsed = t_start.elapsed();
        if *at > elapsed {
            std::thread::sleep(*at - elapsed);
        }
        let addr = addr.clone();
        let function = function.clone();
        let results = results.clone();
        let measured = i >= discard;
        handles.push(pool.submit(move || {
            let t0 = Instant::now();
            let resp = http_get(&addr, &format!("/v1/invoke/{function}?seed={i}"), tmo);
            let ok = matches!(&resp, Ok(r) if r.status == 200);
            let cold = matches!(&resp, Ok(r) if r.body_str().contains("\"cold\""));
            if measured {
                results.lock().unwrap().push((ok, t0.elapsed().as_secs_f64(), cold));
            }
        }));
    }
    for h in handles {
        h.join();
    }
    let wall = t_start.elapsed().as_secs_f64();
    let rows = results.lock().unwrap().clone();
    let ok: Vec<f64> = rows.iter().filter(|(s, _, _)| *s).map(|(_, l, _)| *l).collect();
    let cold = rows.iter().filter(|(_, _, c)| *c).count();
    let failed = rows.len() - ok.len();
    let s = Summary::from_samples(&ok);
    println!(
        "done in {wall:.1}s: {} ok ({cold} cold), {failed} failed, {:.2} req/s",
        ok.len(),
        ok.len() as f64 / wall
    );
    println!(
        "latency: mean={:.3}s ±{:.3} p50={:.3}s p95={:.3}s p99={:.3}s max={:.3}s",
        s.mean, s.ci95, s.p50, s.p95, s.p99, s.max
    );
    Ok(())
}

fn cmd_experiment(argv: &[String]) -> Result<()> {
    let cmd = Command::new("experiment", "run a paper experiment")
        .flag("id", "table1|fig1..fig10|abl-*|all", Some("table1"))
        .flag("engine", "pjrt | mock", None)
        .flag("shards", "engine shards", Some("2"))
        .flag("out", "results directory", Some("results"))
        .flag("scale", "workload scale factor for fig8-10", Some("0.2"))
        .flag("reps", "warm-probe repetitions", Some("25"))
        .flag("config", "platform config TOML", None);
    if argv.iter().any(|a| a == "--help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or(args.get_or("id", "table1"))
        .to_string();
    // Default engine per experiment family: real artifacts for the
    // sequential probes, calibrated mock for the concurrency ramp
    // (see DESIGN.md §4).
    let default_engine = if id.starts_with("fig8")
        || id.starts_with("fig9")
        || id.starts_with("fig10")
        || id.starts_with("abl")
    {
        "mock"
    } else {
        "pjrt"
    };
    let kind = match args.get("engine").unwrap_or(default_engine) {
        "pjrt" => EngineKind::Pjrt,
        "mock" => EngineKind::Mock,
        other => bail!("unknown engine {other:?}"),
    };
    let mut ctx = ExpCtx::new(kind);
    ctx.config = load_config(&args)?;
    ctx.engine_shards = args.get_u64("shards")?.unwrap_or(2) as usize;
    ctx.out_dir = args.get_or("out", "results").into();
    ctx.scale = args.get_f64("scale")?.unwrap_or(0.2);
    ctx.reps = args.get_u64("reps")?.unwrap_or(25) as usize;
    experiments::run(&id, &ctx)
}

fn cmd_price_table(argv: &[String]) -> Result<()> {
    let cmd = Command::new("price-table", "print Table 1")
        .flag("config", "platform config TOML", None)
        .flag("out", "results directory", Some("results"));
    let args = cmd.parse(argv)?;
    let mut ctx = ExpCtx::new(EngineKind::Mock);
    ctx.config = load_config(&args)?;
    ctx.out_dir = args.get_or("out", "results").into();
    experiments::run_table1(&ctx)
}

fn cmd_models(argv: &[String]) -> Result<()> {
    let cmd = Command::new("models", "list the AOT model zoo")
        .flag("config", "platform config TOML", None);
    let args = cmd.parse(argv)?;
    let config = load_config(&args)?;
    let zoo = Zoo::load(Path::new(&config.artifacts_dir))?;
    println!(
        "zoo: {}x{} input, seed {} ({} models)",
        zoo.height,
        zoo.width,
        zoo.seed,
        zoo.models.len()
    );
    for m in zoo.models.values() {
        println!(
            "  {:12} params={:3} arrays {:6.1} MB  flops={:6.2} G  peak={} MB  variants={:?}",
            m.name,
            m.param_count,
            m.param_bytes as f64 / 1e6,
            m.flops as f64 / 1e9,
            m.paper_peak_mem_mb,
            m.artifacts.keys().collect::<Vec<_>>()
        );
    }
    Ok(())
}
