//! Bounded/unbounded MPMC channel on Mutex + Condvar.
//!
//! `std::sync::mpsc` is single-consumer; the invoker needs multiple
//! worker threads pulling from one queue, so this implements a small
//! MPMC with close semantics and timeouts.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

struct Chan<T> {
    q: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct State<T> {
    items: VecDeque<T>,
    cap: usize,
    closed: bool,
    senders: usize,
    receivers: usize,
}

#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

#[derive(Debug, PartialEq, Eq)]
pub enum RecvError {
    Closed,
    Timeout,
}

pub struct Sender<T>(Arc<Chan<T>>);

pub struct Receiver<T>(Arc<Chan<T>>);

pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap > 0, "bounded channel needs cap > 0");
    make(cap)
}

pub fn unbounded<T>() -> (Sender<T>, Receiver<T>) {
    make(usize::MAX)
}

fn make<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let chan = Arc::new(Chan {
        q: Mutex::new(State {
            items: VecDeque::new(),
            cap,
            closed: false,
            senders: 1,
            receivers: 1,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (Sender(chan.clone()), Receiver(chan))
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.q.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.0.q.lock().unwrap().receivers += 1;
        Receiver(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut g = self.0.q.lock().unwrap();
        g.senders -= 1;
        if g.senders == 0 {
            g.closed = true;
            drop(g);
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut g = self.0.q.lock().unwrap();
        g.receivers -= 1;
        if g.receivers == 0 {
            g.closed = true;
            drop(g);
            self.0.not_full.notify_all();
        }
    }
}

impl<T> Sender<T> {
    /// Blocking send; fails when all receivers dropped.
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut g = self.0.q.lock().unwrap();
        loop {
            if g.closed && g.receivers == 0 {
                return Err(SendError(item));
            }
            if g.items.len() < g.cap {
                g.items.push_back(item);
                drop(g);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            g = self.0.not_full.wait(g).unwrap();
        }
    }

    /// Non-blocking send; fails when full or closed.
    pub fn try_send(&self, item: T) -> Result<(), SendError<T>> {
        let mut g = self.0.q.lock().unwrap();
        if (g.closed && g.receivers == 0) || g.items.len() >= g.cap {
            return Err(SendError(item));
        }
        g.items.push_back(item);
        drop(g);
        self.0.not_empty.notify_one();
        Ok(())
    }

    /// Queue depth (for backpressure metrics).
    pub fn len(&self) -> usize {
        self.0.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `Closed` once all senders dropped and drained.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut g = self.0.q.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.0.not_full.notify_one();
                return Ok(item);
            }
            if g.closed {
                return Err(RecvError::Closed);
            }
            g = self.0.not_empty.wait(g).unwrap();
        }
    }

    /// Receive with timeout.
    pub fn recv_timeout(&self, d: Duration) -> Result<T, RecvError> {
        let deadline = std::time::Instant::now() + d;
        let mut g = self.0.q.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.0.not_full.notify_one();
                return Ok(item);
            }
            if g.closed {
                return Err(RecvError::Closed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let (guard, res) = self.0.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.items.is_empty() {
                return Err(RecvError::Timeout);
            }
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut g = self.0.q.lock().unwrap();
        let item = g.items.pop_front();
        if item.is_some() {
            drop(g);
            self.0.not_full.notify_one();
        }
        item
    }

    pub fn len(&self) -> usize {
        self.0.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn send_recv_fifo() {
        let (tx, rx) = unbounded();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
    }

    #[test]
    fn closed_on_sender_drop() {
        let (tx, rx) = unbounded::<u32>();
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Err(RecvError::Closed));
    }

    #[test]
    fn send_fails_after_receiver_drop() {
        let (tx, rx) = unbounded::<u32>();
        drop(rx);
        assert_eq!(tx.send(5), Err(SendError(5)));
    }

    #[test]
    fn bounded_try_send_full() {
        let (tx, _rx) = bounded(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert_eq!(tx.try_send(3), Err(SendError(3)));
        assert_eq!(tx.len(), 2);
    }

    #[test]
    fn bounded_send_blocks_until_recv() {
        let (tx, rx) = bounded(1);
        tx.send(1).unwrap();
        let t = thread::spawn(move || {
            tx.send(2).unwrap(); // blocks until rx drains
            tx
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv(), Ok(1));
        let tx = t.join().unwrap();
        assert_eq!(rx.recv(), Ok(2));
        drop(tx);
    }

    #[test]
    fn recv_timeout() {
        let (_tx, rx) = bounded::<u32>(1);
        let t0 = std::time::Instant::now();
        assert_eq!(rx.recv_timeout(Duration::from_millis(30)), Err(RecvError::Timeout));
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(8);
        let n_producers = 4;
        let per = 250;
        let mut handles = Vec::new();
        for p in 0..n_producers {
            let tx = tx.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * per + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for h in handles {
            h.join().unwrap();
        }
        let mut all: Vec<usize> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n_producers * per).collect::<Vec<_>>());
    }

    #[test]
    fn try_recv_nonblocking() {
        let (tx, rx) = unbounded();
        assert_eq!(rx.try_recv(), None);
        tx.send(9).unwrap();
        assert_eq!(rx.try_recv(), Some(9));
    }
}
