//! Execution substrate: a work-stealing-free but contention-light
//! thread pool and a bounded MPMC channel, built on `std` only (no
//! tokio in the offline dep closure).
//!
//! The platform uses the pool to run container executions; the gateway
//! uses it for connection handling. Bounded channels give natural
//! backpressure on the invoke queue.

pub mod channel;
mod pool;

pub use channel::{bounded, unbounded, Receiver, RecvError, SendError, Sender};
pub use pool::ThreadPool;
