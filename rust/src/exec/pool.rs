//! Fixed-size thread pool with graceful shutdown and job handles.

use super::channel::{unbounded, Receiver, Sender};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    active: Arc<AtomicUsize>,
    name: String,
}

impl ThreadPool {
    pub fn new(threads: usize, name: &str) -> Self {
        assert!(threads > 0, "thread pool needs >= 1 thread");
        let (tx, rx): (Sender<Job>, Receiver<Job>) = unbounded();
        let active = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = rx.clone();
                let active = active.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            active.fetch_add(1, Ordering::SeqCst);
                            job();
                            active.fetch_sub(1, Ordering::SeqCst);
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx: Some(tx), workers, active, name: name.to_string() }
    }

    /// Fire-and-forget execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .unwrap_or_else(|_| panic!("pool {} has no workers", self.name));
    }

    /// Execution with a join handle carrying the result.
    pub fn submit<T, F>(&self, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let slot = Arc::new((Mutex::new(None::<T>), Condvar::new()));
        let slot2 = slot.clone();
        self.execute(move || {
            let v = f();
            let (m, cv) = &*slot2;
            *m.lock().unwrap() = Some(v);
            cv.notify_all();
        });
        JobHandle { slot }
    }

    /// Jobs currently executing (not queued).
    pub fn active(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Queue depth.
    pub fn queued(&self) -> usize {
        self.tx.as_ref().map_or(0, |t| t.len())
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel; workers drain remaining jobs then exit.
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

pub struct JobHandle<T> {
    slot: Arc<(Mutex<Option<T>>, Condvar)>,
}

impl<T> JobHandle<T> {
    /// Block until the job finishes; returns its result.
    pub fn join(self) -> T {
        let (m, cv) = &*self.slot;
        let mut g = m.lock().unwrap();
        while g.is_none() {
            g = cv.wait(g).unwrap();
        }
        g.take().unwrap()
    }

    pub fn is_done(&self) -> bool {
        self.slot.0.lock().unwrap().is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // drains queue
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_returns_result() {
        let pool = ThreadPool::new(2, "t");
        let h = pool.submit(|| 6 * 7);
        assert_eq!(h.join(), 42);
    }

    #[test]
    fn submit_many_parallel() {
        let pool = ThreadPool::new(4, "t");
        let handles: Vec<_> = (0..50).map(|i| pool.submit(move || i * i)).collect();
        let results: Vec<i32> = handles.into_iter().map(|h| h.join()).collect();
        assert_eq!(results, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn active_count_tracks_running() {
        let pool = ThreadPool::new(2, "t");
        let h1 = pool.submit(|| std::thread::sleep(Duration::from_millis(60)));
        let h2 = pool.submit(|| std::thread::sleep(Duration::from_millis(60)));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(pool.active(), 2);
        h1.join();
        h2.join();
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(pool.active(), 0);
    }

    #[test]
    fn is_done_flips() {
        let pool = ThreadPool::new(1, "t");
        let h = pool.submit(|| std::thread::sleep(Duration::from_millis(30)));
        assert!(!h.is_done());
        std::thread::sleep(Duration::from_millis(60));
        assert!(h.is_done());
        h.join();
    }

    #[test]
    #[should_panic(expected = ">= 1 thread")]
    fn zero_threads_panics() {
        let _ = ThreadPool::new(0, "t");
    }
}
