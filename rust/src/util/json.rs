//! Minimal JSON parser + writer (no serde in the offline dep closure).
//!
//! Parses the AOT manifests (`artifacts/*.json`) and serializes
//! experiment results. Full JSON per RFC 8259 minus some exotica:
//! surrogate-pair escapes are parsed; numbers are f64 (manifest values
//! fit exactly — param counts < 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= 2f64.powi(53) {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj.field` chain lookup: `j.path(&["artifacts", "pallas", "infer"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // High surrogate: must be followed by \uXXXX low.
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape char")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------- writer

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builder for result serialization.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        assert_eq!(j.path(&["c"]).unwrap().as_str(), Some("d"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parses_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str(), Some("😀"));
    }

    #[test]
    fn parses_utf8_passthrough() {
        let j = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo — ok"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("\"bad \\x escape\"").is_err());
    }

    #[test]
    fn as_u64_bounds() {
        assert_eq!(Json::Num(5.0).as_u64(), Some(5));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,"x\n"],"b":{"c":true,"d":null}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "name": "squeezenet", "param_count": 52,
          "param_bytes": 4988808, "flops": 1670000000,
          "params": [{"name": "conv1.w", "shape": [7, 7, 3, 96]}],
          "artifacts": {"pallas": {"init": "squeezenet_init.hlo.txt"}}
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("param_count").unwrap().as_u64(), Some(52));
        assert_eq!(
            j.path(&["artifacts", "pallas", "init"]).unwrap().as_str(),
            Some("squeezenet_init.hlo.txt")
        );
        let shape = j.get("params").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.iter().map(|v| v.as_u64().unwrap()).collect::<Vec<_>>(), vec![7, 7, 3, 96]);
    }
}
