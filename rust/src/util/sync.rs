//! Poison-tolerant locking primitives.
//!
//! The platform's shared state (warm pool, batch windows, queue depths,
//! metrics shards) is guarded by `std::sync::Mutex`. A panic on one
//! invocation thread — e.g. a batch leader dying mid-forward-pass —
//! poisons every mutex it held, and a bare `.lock().unwrap()` on any
//! other thread then turns that single failure into a platform-wide
//! cascade of panics.
//!
//! None of the platform's critical sections leave state torn on panic:
//! they push/pop whole items, or RAII guards (`BatchLeader`,
//! `QueueTicket`) restore the invariant on drop. Poison is therefore
//! noise for us, not a correctness signal, and every lock acquisition
//! in non-test platform code goes through [`plock`] / [`pwait_timeout`]
//! instead of `.lock().unwrap()`. The `poisoned-lock-unwrap` rule in
//! `pallas-lint` enforces this.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn plock<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait_timeout` that recovers from poison instead of
/// panicking. Callers must still re-check their predicate in a loop —
/// this only bounds the park so shutdown / generation bumps are never
/// missed forever.
pub fn pwait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur)
        .unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn plock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        // A bare .lock().unwrap() would panic here; plock recovers.
        let mut g = plock(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*plock(&m), 8);
    }

    #[test]
    fn pwait_timeout_times_out_and_recovers() {
        let m = Mutex::new(false);
        let cv = Condvar::new();
        let g = plock(&m);
        let (g, res) = pwait_timeout(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
        assert!(!*g);
    }

    #[test]
    fn pwait_timeout_survives_poison() {
        let pair = Arc::new((Mutex::new(0u32), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let _g = p2.0.lock().unwrap();
            panic!("poison the waitable pair");
        })
        .join();
        assert!(pair.0.is_poisoned());
        let g = plock(&pair.0);
        let (g, _res) = pwait_timeout(&pair.1, g, Duration::from_millis(1));
        assert_eq!(*g, 0);
    }
}
