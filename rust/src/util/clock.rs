//! Clock abstraction: the platform never calls `Instant::now()` directly.
//!
//! The paper's cold-start experiment separates requests by **10 minutes**
//! (5 requests x 10 min = 50 min per memory size x 12 sizes x 3 models).
//! Re-running that in real time is absurd, so every time-dependent
//! component (keep-alive eviction, billing timestamps, workload
//! schedules) reads a [`Clock`].  Experiments run on [`VirtualClock`],
//! where sleeps complete instantly by advancing a logical now; the live
//! gateway runs on [`SystemClock`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Nanoseconds since an arbitrary epoch.
pub type Nanos = u64;

pub trait Clock: Send + Sync {
    /// Current time in nanoseconds since the clock's epoch.
    fn now(&self) -> Nanos;

    /// Block the calling thread for `d` (virtual clocks may return
    /// immediately after advancing logical time).
    fn sleep(&self, d: Duration);

    /// True when `sleep` consumes wall time.
    fn is_real(&self) -> bool;

    fn now_secs(&self) -> f64 {
        self.now() as f64 / 1e9
    }
}

/// Wall-clock time via `std::time::Instant`.
pub struct SystemClock {
    epoch: std::time::Instant,
}

impl SystemClock {
    pub fn new() -> Self {
        Self { epoch: std::time::Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now(&self) -> Nanos {
        self.epoch.elapsed().as_nanos() as Nanos
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }

    fn is_real(&self) -> bool {
        true
    }
}

/// Discrete-event virtual clock shared across threads.
///
/// `sleep(d)` registers a waiter at `now + d` and parks; whichever
/// thread calls [`VirtualClock::advance`] (usually the experiment
/// driver) moves `now` forward and wakes every waiter whose deadline
/// passed.  With `auto_advance`, a sleep from the *only* active waiter
/// advances the clock itself — single-threaded experiments then never
/// block at all.
pub struct VirtualClock {
    now: AtomicU64,
    inner: Mutex<Waiters>,
    cv: Condvar,
    auto_advance: bool,
}

struct Waiters {
    deadlines: Vec<Nanos>,
    sleepers: usize,
    threads: usize,
}

impl VirtualClock {
    /// A clock where sleeps advance time immediately (single driver).
    pub fn auto() -> Arc<Self> {
        Arc::new(Self {
            now: AtomicU64::new(0),
            inner: Mutex::new(Waiters { deadlines: Vec::new(), sleepers: 0, threads: 1 }),
            cv: Condvar::new(),
            auto_advance: true,
        })
    }

    /// A clock driven by explicit [`advance`](Self::advance) calls;
    /// `threads` is the number of participating worker threads (used to
    /// detect quiescence in multi-threaded simulations).
    pub fn manual(threads: usize) -> Arc<Self> {
        Arc::new(Self {
            now: AtomicU64::new(0),
            inner: Mutex::new(Waiters { deadlines: Vec::new(), sleepers: 0, threads }),
            cv: Condvar::new(),
            auto_advance: false,
        })
    }

    /// Advance logical time to `t` (no-op if in the past) and wake
    /// every sleeper whose deadline has been reached.
    pub fn advance_to(&self, t: Nanos) {
        let mut cur = self.now.load(Ordering::SeqCst);
        while cur < t {
            match self.now.compare_exchange(cur, t, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
        let mut g = self.inner.lock().unwrap();
        let now = self.now.load(Ordering::SeqCst);
        g.deadlines.retain(|&d| d > now);
        drop(g);
        self.cv.notify_all();
    }

    pub fn advance(&self, d: Duration) {
        self.advance_to(self.now.load(Ordering::SeqCst) + d.as_nanos() as Nanos);
    }

    /// Earliest pending sleeper deadline, if any.
    pub fn next_deadline(&self) -> Option<Nanos> {
        let g = self.inner.lock().unwrap();
        g.deadlines.iter().copied().min()
    }

    /// Number of threads currently blocked in `sleep`.
    pub fn sleeper_count(&self) -> usize {
        self.inner.lock().unwrap().sleepers
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Nanos {
        self.now.load(Ordering::SeqCst)
    }

    fn sleep(&self, d: Duration) {
        if d.is_zero() {
            return;
        }
        let deadline = self.now() + d.as_nanos() as Nanos;
        if self.auto_advance {
            self.advance_to(deadline);
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.deadlines.push(deadline);
        g.sleepers += 1;
        // If every participating thread is now asleep, time can only
        // move forward: advance to the earliest deadline ourselves.
        while self.now() < deadline {
            let all_asleep = g.sleepers >= g.threads;
            if all_asleep {
                let min = g.deadlines.iter().copied().min().unwrap_or(deadline);
                drop(g);
                self.advance_to(min);
                g = self.inner.lock().unwrap();
            } else {
                g = self.cv.wait(g).unwrap();
            }
        }
        g.sleepers -= 1;
        drop(g);
    }

    fn is_real(&self) -> bool {
        false
    }
}

/// Pacing for a condvar waiter that must stay live on virtual clocks,
/// shared by the waitable warm pool and the batch collector.
///
/// On a real clock a waiter simply sleeps until its deadline. On a
/// virtual clock a wall timeout cannot advance virtual time, so the
/// waiter wakes in short wall slices — and, after a few slices in
/// which nothing progressed, starts advancing the virtual clock toward
/// its own deadline, ensuring a (virtual) deadline expiry even when it
/// is the only active thread (e.g. the single-threaded closed-loop
/// driver). Cross-thread condvar wakeups still work throughout:
/// worker threads are real even when time is not.
#[derive(Default)]
pub struct VirtualWaitPacer {
    idle_slices: u32,
}

impl VirtualWaitPacer {
    /// Wall-clock wait quantum on non-real clocks: short enough that
    /// a virtual-deadline expiry is noticed promptly, long enough not
    /// to busy-spin.
    pub const WAIT_SLICE: Duration = Duration::from_millis(1);
    /// Empty wall slices tolerated before a parked waiter on a
    /// virtual clock starts advancing virtual time itself.
    const GRACE_SLICES: u32 = 3;
    /// Virtual time consumed per further empty slice; bounded by the
    /// waiter's remaining deadline.
    const STEP: Duration = Duration::from_millis(25);

    pub fn new() -> Self {
        Self::default()
    }

    /// Timeout for the next condvar wait toward `deadline` (absolute
    /// platform-clock nanos): the full remainder on a real clock, one
    /// short slice on a virtual one.
    pub fn next_timeout(&self, clock: &dyn Clock, deadline: Nanos) -> Duration {
        if clock.is_real() {
            Duration::from_nanos(deadline.saturating_sub(clock.now()).max(1))
        } else {
            Self::WAIT_SLICE
        }
    }

    /// Record one wait outcome; `progressed` means the condition the
    /// caller is waiting on changed. After the grace, an unprogressed
    /// waiter on a virtual clock advances the clock one bounded step
    /// toward `deadline`.
    pub fn on_wake(&mut self, clock: &dyn Clock, progressed: bool, deadline: Nanos) {
        if progressed {
            self.idle_slices = 0;
            return;
        }
        if clock.is_real() {
            return;
        }
        self.idle_slices += 1;
        if self.idle_slices >= Self::GRACE_SLICES {
            let now = clock.now();
            if now < deadline {
                clock.sleep(Self::STEP.min(Duration::from_nanos(deadline - now)));
            }
        }
    }
}

/// Test clock settable from the outside, no waiter machinery.
pub struct ManualClock(pub AtomicU64);

impl ManualClock {
    pub fn new() -> Arc<Self> {
        Arc::new(Self(AtomicU64::new(0)))
    }

    pub fn set(&self, t: Nanos) {
        self.0.store(t, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now(&self) -> Nanos {
        self.0.load(Ordering::SeqCst)
    }

    fn sleep(&self, d: Duration) {
        self.0.fetch_add(d.as_nanos() as Nanos, Ordering::SeqCst);
    }

    fn is_real(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_monotonic() {
        let c = SystemClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        assert!(c.is_real());
    }

    #[test]
    fn auto_virtual_clock_sleep_advances() {
        let c = VirtualClock::auto();
        assert_eq!(c.now(), 0);
        c.sleep(Duration::from_secs(600));
        assert_eq!(c.now(), 600_000_000_000);
        assert!(!c.is_real());
    }

    #[test]
    fn auto_clock_zero_sleep_noop() {
        let c = VirtualClock::auto();
        c.sleep(Duration::ZERO);
        assert_eq!(c.now(), 0);
    }

    #[test]
    fn manual_clock_set_and_sleep() {
        let c = ManualClock::new();
        c.set(5);
        assert_eq!(c.now(), 5);
        c.sleep(Duration::from_nanos(10));
        assert_eq!(c.now(), 15);
    }

    #[test]
    fn virtual_clock_advance_wakes_sleeper() {
        let c = VirtualClock::manual(2);
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            c2.sleep(Duration::from_secs(10));
            c2.now()
        });
        // Wait until the sleeper registers.
        while c.sleeper_count() == 0 {
            std::thread::yield_now();
        }
        assert_eq!(c.next_deadline(), Some(10_000_000_000));
        c.advance(Duration::from_secs(10));
        assert_eq!(h.join().unwrap(), 10_000_000_000);
    }

    #[test]
    fn virtual_clock_all_asleep_self_advances() {
        let c = VirtualClock::manual(1);
        // Single participating thread: sleep must self-advance.
        c.sleep(Duration::from_secs(3));
        assert_eq!(c.now(), 3_000_000_000);
    }

    #[test]
    fn pacer_slices_on_virtual_clock_and_self_advances_after_grace() {
        let manual = ManualClock::new();
        let clock: &dyn Clock = &*manual;
        let mut p = VirtualWaitPacer::new();
        let deadline = 100_000_000; // 100 ms virtual
        assert_eq!(p.next_timeout(clock, deadline), VirtualWaitPacer::WAIT_SLICE);
        // Progress keeps resetting the grace: no time advance.
        for _ in 0..10 {
            p.on_wake(clock, true, deadline);
        }
        assert_eq!(clock.now(), 0);
        // Idle wakes burn the grace, then advance bounded steps until
        // the deadline is reached exactly.
        for _ in 0..10 {
            p.on_wake(clock, false, deadline);
        }
        assert!(clock.now() > 0, "self-advanced after the grace");
        while clock.now() < deadline {
            p.on_wake(clock, false, deadline);
        }
        assert_eq!(clock.now(), deadline, "advance is bounded by the deadline");
        p.on_wake(clock, false, deadline); // at the deadline: no-op
        assert_eq!(clock.now(), deadline);
    }

    #[test]
    fn pacer_real_clock_sleeps_remainder_and_never_advances() {
        let real = SystemClock::new();
        let clock: &dyn Clock = &real;
        let mut p = VirtualWaitPacer::new();
        let deadline = clock.now() + 50_000_000;
        let t = p.next_timeout(clock, deadline);
        assert!(t > Duration::from_millis(1), "real clocks wait the remainder, {t:?}");
        for _ in 0..10 {
            p.on_wake(clock, false, deadline); // must not sleep wall time
        }
        // An expired deadline still yields a positive (floor 1 ns)
        // timeout so wait_timeout never panics.
        assert!(p.next_timeout(clock, 0) >= Duration::from_nanos(1));
    }

    #[test]
    fn virtual_clock_two_sleepers_ordered_wakeup() {
        let c = VirtualClock::manual(2);
        let (c1, c2) = (c.clone(), c.clone());
        let h1 = std::thread::spawn(move || {
            c1.sleep(Duration::from_secs(1));
            c1.now()
        });
        let h2 = std::thread::spawn(move || {
            c2.sleep(Duration::from_secs(2));
            c2.now()
        });
        let t1 = h1.join().unwrap();
        let t2 = h2.join().unwrap();
        assert!(t1 >= 1_000_000_000);
        assert!(t2 >= 2_000_000_000);
    }
}
