//! Clock abstraction: the platform never calls `Instant::now()` directly.
//!
//! The paper's cold-start experiment separates requests by **10 minutes**
//! (5 requests x 10 min = 50 min per memory size x 12 sizes x 3 models).
//! Re-running that in real time is absurd, so every time-dependent
//! component (keep-alive eviction, billing timestamps, workload
//! schedules) reads a [`Clock`].  Experiments run on [`VirtualClock`],
//! where sleeps complete instantly by advancing a logical now; the live
//! gateway runs on [`SystemClock`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Nanoseconds since an arbitrary epoch.
pub type Nanos = u64;

pub trait Clock: Send + Sync {
    /// Current time in nanoseconds since the clock's epoch.
    fn now(&self) -> Nanos;

    /// Block the calling thread for `d` (virtual clocks may return
    /// immediately after advancing logical time).
    fn sleep(&self, d: Duration);

    /// True when `sleep` consumes wall time.
    fn is_real(&self) -> bool;

    fn now_secs(&self) -> f64 {
        self.now() as f64 / 1e9
    }
}

/// Wall-clock time via `std::time::Instant`.
pub struct SystemClock {
    epoch: std::time::Instant,
}

impl SystemClock {
    pub fn new() -> Self {
        Self { epoch: std::time::Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now(&self) -> Nanos {
        self.epoch.elapsed().as_nanos() as Nanos
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }

    fn is_real(&self) -> bool {
        true
    }
}

/// Discrete-event virtual clock shared across threads.
///
/// `sleep(d)` registers a waiter at `now + d` and parks; whichever
/// thread calls [`VirtualClock::advance`] (usually the experiment
/// driver) moves `now` forward and wakes every waiter whose deadline
/// passed.  With `auto_advance`, a sleep from the *only* active waiter
/// advances the clock itself — single-threaded experiments then never
/// block at all.
pub struct VirtualClock {
    now: AtomicU64,
    inner: Mutex<Waiters>,
    cv: Condvar,
    auto_advance: bool,
}

struct Waiters {
    deadlines: Vec<Nanos>,
    sleepers: usize,
    threads: usize,
}

impl VirtualClock {
    /// A clock where sleeps advance time immediately (single driver).
    pub fn auto() -> Arc<Self> {
        Arc::new(Self {
            now: AtomicU64::new(0),
            inner: Mutex::new(Waiters { deadlines: Vec::new(), sleepers: 0, threads: 1 }),
            cv: Condvar::new(),
            auto_advance: true,
        })
    }

    /// A clock driven by explicit [`advance`](Self::advance) calls;
    /// `threads` is the number of participating worker threads (used to
    /// detect quiescence in multi-threaded simulations).
    pub fn manual(threads: usize) -> Arc<Self> {
        Arc::new(Self {
            now: AtomicU64::new(0),
            inner: Mutex::new(Waiters { deadlines: Vec::new(), sleepers: 0, threads }),
            cv: Condvar::new(),
            auto_advance: false,
        })
    }

    /// Advance logical time to `t` (no-op if in the past) and wake
    /// every sleeper whose deadline has been reached.
    pub fn advance_to(&self, t: Nanos) {
        let mut cur = self.now.load(Ordering::SeqCst);
        while cur < t {
            match self.now.compare_exchange(cur, t, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
        let mut g = self.inner.lock().unwrap();
        let now = self.now.load(Ordering::SeqCst);
        g.deadlines.retain(|&d| d > now);
        drop(g);
        self.cv.notify_all();
    }

    pub fn advance(&self, d: Duration) {
        self.advance_to(self.now.load(Ordering::SeqCst) + d.as_nanos() as Nanos);
    }

    /// Earliest pending sleeper deadline, if any.
    pub fn next_deadline(&self) -> Option<Nanos> {
        let g = self.inner.lock().unwrap();
        g.deadlines.iter().copied().min()
    }

    /// Number of threads currently blocked in `sleep`.
    pub fn sleeper_count(&self) -> usize {
        self.inner.lock().unwrap().sleepers
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Nanos {
        self.now.load(Ordering::SeqCst)
    }

    fn sleep(&self, d: Duration) {
        if d.is_zero() {
            return;
        }
        let deadline = self.now() + d.as_nanos() as Nanos;
        if self.auto_advance {
            self.advance_to(deadline);
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.deadlines.push(deadline);
        g.sleepers += 1;
        // If every participating thread is now asleep, time can only
        // move forward: advance to the earliest deadline ourselves.
        while self.now() < deadline {
            let all_asleep = g.sleepers >= g.threads;
            if all_asleep {
                let min = g.deadlines.iter().copied().min().unwrap_or(deadline);
                drop(g);
                self.advance_to(min);
                g = self.inner.lock().unwrap();
            } else {
                g = self.cv.wait(g).unwrap();
            }
        }
        g.sleepers -= 1;
        drop(g);
    }

    fn is_real(&self) -> bool {
        false
    }
}

/// Test clock settable from the outside, no waiter machinery.
pub struct ManualClock(pub AtomicU64);

impl ManualClock {
    pub fn new() -> Arc<Self> {
        Arc::new(Self(AtomicU64::new(0)))
    }

    pub fn set(&self, t: Nanos) {
        self.0.store(t, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now(&self) -> Nanos {
        self.0.load(Ordering::SeqCst)
    }

    fn sleep(&self, d: Duration) {
        self.0.fetch_add(d.as_nanos() as Nanos, Ordering::SeqCst);
    }

    fn is_real(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_monotonic() {
        let c = SystemClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        assert!(c.is_real());
    }

    #[test]
    fn auto_virtual_clock_sleep_advances() {
        let c = VirtualClock::auto();
        assert_eq!(c.now(), 0);
        c.sleep(Duration::from_secs(600));
        assert_eq!(c.now(), 600_000_000_000);
        assert!(!c.is_real());
    }

    #[test]
    fn auto_clock_zero_sleep_noop() {
        let c = VirtualClock::auto();
        c.sleep(Duration::ZERO);
        assert_eq!(c.now(), 0);
    }

    #[test]
    fn manual_clock_set_and_sleep() {
        let c = ManualClock::new();
        c.set(5);
        assert_eq!(c.now(), 5);
        c.sleep(Duration::from_nanos(10));
        assert_eq!(c.now(), 15);
    }

    #[test]
    fn virtual_clock_advance_wakes_sleeper() {
        let c = VirtualClock::manual(2);
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            c2.sleep(Duration::from_secs(10));
            c2.now()
        });
        // Wait until the sleeper registers.
        while c.sleeper_count() == 0 {
            std::thread::yield_now();
        }
        assert_eq!(c.next_deadline(), Some(10_000_000_000));
        c.advance(Duration::from_secs(10));
        assert_eq!(h.join().unwrap(), 10_000_000_000);
    }

    #[test]
    fn virtual_clock_all_asleep_self_advances() {
        let c = VirtualClock::manual(1);
        // Single participating thread: sleep must self-advance.
        c.sleep(Duration::from_secs(3));
        assert_eq!(c.now(), 3_000_000_000);
    }

    #[test]
    fn virtual_clock_two_sleepers_ordered_wakeup() {
        let c = VirtualClock::manual(2);
        let (c1, c2) = (c.clone(), c.clone());
        let h1 = std::thread::spawn(move || {
            c1.sleep(Duration::from_secs(1));
            c1.now()
        });
        let h2 = std::thread::spawn(move || {
            c2.sleep(Duration::from_secs(2));
            c2.now()
        });
        let t1 = h1.join().unwrap();
        let t2 = h2.join().unwrap();
        assert!(t1 >= 1_000_000_000);
        assert!(t2 >= 2_000_000_000);
    }
}
