//! Deterministic PRNG (SplitMix64 + helpers).
//!
//! No `rand` crate in the offline dep closure, so workloads, jitter
//! models and property tests use this. SplitMix64 passes BigCrush, is
//! trivially seedable, and every experiment records its seed so runs
//! are reproducible bit-for-bit.

/// SplitMix64 (Steele, Lea, Flood 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "gen_range: empty range [{lo}, {hi})");
        // Lemire's method without bias correction is fine for ranges
        // ≪ 2^64 (worst-case bias < 2^-40 for our range sizes).
        lo + (((self.next_u64() as u128 * (hi - lo) as u128) >> 64) as u64)
    }

    /// Exponential with mean `mean` (inter-arrival sampling).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        mean + std * z
    }

    /// Log-normal with the *resulting* distribution's median `median`
    /// and shape `sigma` (used for the sandbox bootstrap delay model).
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        (self.normal(0.0, sigma)).exp() * median
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, (i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = SplitMix64::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.gen_range(5, 15);
            assert!((5..15).contains(&v));
            seen[(v - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range reached");
    }

    #[test]
    #[should_panic(expected = "empty range")]
    fn gen_range_rejects_empty() {
        SplitMix64::new(0).gen_range(5, 5);
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = SplitMix64::new(11);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.exponential(2.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn normal_moments_close() {
        let mut r = SplitMix64::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.1, "mean={mean}");
        assert!((var.sqrt() - 3.0).abs() < 0.1, "std={}", var.sqrt());
    }

    #[test]
    fn lognormal_median_close() {
        let mut r = SplitMix64::new(17);
        let n = 20_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(0.25, 0.5)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        assert!((median - 0.25).abs() < 0.02, "median={median}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>(), "shuffled order differs");
    }
}
