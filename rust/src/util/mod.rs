//! Shared substrates: virtual/real clock, deterministic PRNG, JSON.

pub mod clock;
pub mod json;
pub mod rng;
pub mod sync;

pub use clock::{Clock, ManualClock, SystemClock, VirtualClock, VirtualWaitPacer};
pub use rng::SplitMix64;
pub use sync::{plock, pwait_timeout};
