//! # lambdaserve
//!
//! A self-contained serverless (FaaS) platform for deep-learning
//! inference, reproducing *"Serving deep learning models in a
//! serverless platform"* (Ishakian, Muthusamy, Slominski — 2017).
//!
//! The paper measured MXNet image classifiers (SqueezeNet, ResNet-18,
//! ResNeXt-50) on AWS Lambda across memory sizes, under cold starts,
//! warm starts, and a step-shaped scalability load. This crate builds
//! the platform itself — container pool with cold/warm lifecycle,
//! memory-proportional CPU governor, 100 ms-granular billing, HTTP
//! gateway — and serves *real* inference through AOT-compiled XLA
//! artifacts (JAX + Pallas at build time, PJRT-CPU at run time; Python
//! is never on the request path).
//!
//! Layout (see DESIGN.md for the full inventory):
//!
//! * substrates: [`util`], [`exec`], [`configparse`], [`httpd`],
//!   [`cliparse`], [`stats`], [`testkit`]
//! * the FaaS core: [`platform`]
//! * model execution: [`runtime`]
//! * measurement: [`workload`], [`experiments`]
//! * front door: [`gateway`]
//! * invariants: [`lints`] (the `pallas_lint` binary, see LINTS.md)

pub mod cliparse;
pub mod configparse;
pub mod exec;
pub mod experiments;
pub mod gateway;
pub mod httpd;
pub mod lints;
pub mod platform;
pub mod runtime;
pub mod stats;
pub mod testkit;
pub mod util;
pub mod workload;
