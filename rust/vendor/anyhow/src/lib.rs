//! Minimal, dependency-free drop-in for the subset of the `anyhow`
//! error-handling API that lambdaserve uses.
//!
//! The container image this repo builds in has no crates-io registry,
//! so the real `anyhow` cannot be fetched; this vendored crate keeps
//! the ergonomic surface (`Result`, `Error`, `anyhow!`, `bail!`,
//! `ensure!`, `.context()` / `.with_context()`) with zero external
//! dependencies. Context frames and source chains are flattened to
//! strings: `Display` prints the outermost message, `{:#}` prints the
//! full `outer: ...: root` chain, and `Debug` prints a `Caused by:`
//! listing — matching real anyhow's observable formatting.

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default-parameter shape as
/// the real crate, so `Result<T, SomeOtherError>` also type-checks.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Flattened error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame (used by the `Context` trait).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, frame) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket `From` legal.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*).into())
    };
}

/// `bail!` unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: disk on fire");
        assert_eq!(e.root_cause(), "disk on fire");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.chain().count(), 2);

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn macros_compile_and_format() {
        fn inner(fail: bool) -> Result<u32> {
            ensure!(!fail, "flag was {fail}");
            if fail {
                bail!("unreachable {}", 1);
            }
            Ok(7)
        }
        assert_eq!(inner(false).unwrap(), 7);
        assert_eq!(inner(true).unwrap_err().to_string(), "flag was true");
        let from_expr = anyhow!(String::from("owned message"));
        assert_eq!(from_expr.to_string(), "owned message");
        let fmt = anyhow!("x={}", 3);
        assert_eq!(fmt.to_string(), "x=3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::from(io_err()).context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("disk on fire"));
    }
}
